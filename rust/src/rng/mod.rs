//! Deterministic PRNG + distribution sampling (no `rand` offline —
//! DESIGN.md §8).
//!
//! [`Pcg64`] is PCG-XSL-RR 128/64 — the same generator family numpy's
//! `PCG64` uses — seeded through SplitMix64 so any `u64` seed yields a
//! well-mixed state. Gaussian sampling is polar Box–Muller. Everything
//! here is deterministic across platforms: dataset generation, init
//! selection, and the eval harness all reproduce bit-for-bit from a
//! seed.

/// SplitMix64 — used to expand user seeds into generator state.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// One cached spare normal from Box–Muller.
    spare_normal: Option<f64>,
}

const PCG_MULT: u128 = 0x2360ED051FC65DA44385DF649FCCF645;

impl Pcg64 {
    /// Seed via SplitMix64 expansion; `stream` selects an independent
    /// sequence (used to give each mixture component / worker its own
    /// stream).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let mut sm2 = SplitMix64::new(stream ^ 0xDA3E39CB94B95BDB);
        let i0 = sm2.next_u64() as u128;
        let i1 = sm2.next_u64() as u128;
        let mut rng = Pcg64 {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1, // must be odd
            spare_normal: None,
        };
        rng.next_u64(); // discard first output (decorrelate seeds)
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        // XSL-RR output function
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        let rot = (self.state >> 122) as u32;
        xored.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) — Lemire's rejection method,
    /// unbiased.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "next_below(0)");
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as u64;
            }
            // threshold = 2^64 mod bound = wrapping_neg(bound) % bound
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Standard normal via polar Box–Muller (caches the spare).
    pub fn next_normal(&mut self) -> f64 {
        if let Some(s) = self.spare_normal.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare_normal = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fisher–Yates sample of `k` distinct indices from [0, n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k {k} > n {n}");
        // partial Fisher-Yates over an index map (sparse for big n)
        let mut swaps: std::collections::HashMap<usize, usize> = Default::default();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.next_below((n - i) as u64) as usize;
            let vi = *swaps.get(&i).unwrap_or(&i);
            let vj = *swaps.get(&j).unwrap_or(&j);
            out.push(vj);
            swaps.insert(j, vi);
        }
        out
    }

    /// Sample an index from unnormalized non-negative weights
    /// (k-means++ D² sampling).
    pub fn next_weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "next_weighted: all-zero weights");
        let mut target = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            target -= w;
            if target <= 0.0 {
                return i;
            }
        }
        weights.len() - 1 // fp slack
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_stream_independent() {
        let a: Vec<u64> = {
            let mut r = Pcg64::new(42, 0);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg64::new(42, 0);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let c: Vec<u64> = {
            let mut r = Pcg64::new(42, 1);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::new(7, 0);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Pcg64::new(1, 0);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.next_below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3, 0);
        let n = 50_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        for _ in 0..n {
            let v = r.next_normal();
            sum += v;
            sumsq += v * v;
        }
        let mean = sum / n as f64;
        let var = sumsq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut r = Pcg64::new(9, 0);
        let idx = r.sample_indices(1000, 50);
        assert_eq!(idx.len(), 50);
        let set: std::collections::HashSet<_> = idx.iter().collect();
        assert_eq!(set.len(), 50);
        assert!(idx.iter().all(|&i| i < 1000));
    }

    #[test]
    fn sample_indices_full_permutation() {
        let mut r = Pcg64::new(5, 0);
        let mut idx = r.sample_indices(10, 10);
        idx.sort_unstable();
        assert_eq!(idx, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Pcg64::new(11, 0);
        let w = [0.0, 1.0, 0.0, 9.0];
        let mut counts = [0usize; 4];
        for _ in 0..5000 {
            counts[r.next_weighted(&w)] += 1;
        }
        assert_eq!(counts[0], 0);
        assert_eq!(counts[2], 0);
        let frac = counts[3] as f64 / 5000.0;
        assert!((frac - 0.9).abs() < 0.03, "frac {frac}");
    }

    #[test]
    #[should_panic]
    fn weighted_all_zero_panics() {
        Pcg64::new(0, 0).next_weighted(&[0.0, 0.0]);
    }
}
