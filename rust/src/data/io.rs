//! Dataset (de)serialization.
//!
//! Two formats:
//! - **binary** (`.pkd`): little-endian, magic + dim + n + f32 payload
//!   (+ optional truth labels). Fast path used by the CLI `gen-data` /
//!   `run` round trip for the 1M-point workloads, and the format the
//!   out-of-core [`crate::data::source::FileSource`] streams from.
//! - **CSV**: one point per row, interchange with external tools.
//!
//! All readers return typed errors (DESIGN.md §8 error taxonomy):
//! [`Error::Data`] for content that is present but wrong (bad magic,
//! truncated payload, ragged or non-numeric CSV rows), [`Error::Io`]
//! only when the OS itself fails to read.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::util::chaos;
use crate::util::crc32::{crc32, Crc32, CrcReader};

const MAGIC: &[u8; 8] = b"PARAKMD1";

// ---- artifact integrity plumbing ---------------------------------------

/// Process-wide count of legacy (CRC-less) artifacts read. Surfaced in
/// the run summary so operators know which files predate the integrity
/// trailer and cannot detect bit rot.
static ARTIFACT_WARNINGS: AtomicU64 = AtomicU64::new(0);

/// Legacy-artifact warnings accumulated so far this process.
pub fn artifact_warnings() -> u64 {
    ARTIFACT_WARNINGS.load(Ordering::Relaxed)
}

fn note_legacy_artifact() {
    ARTIFACT_WARNINGS.fetch_add(1, Ordering::Relaxed);
}

fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path.file_name().map(|s| s.to_os_string()).unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

/// Atomic file write: temp file in the same directory, fsync, rename.
/// A crash at any point leaves either the old content or the new —
/// never a torn mix. On failure the destination is untouched (a stale
/// `<name>.tmp` may remain; the next attempt overwrites it).
pub fn atomic_write(path: &Path, bytes: &[u8]) -> Result<()> {
    atomic_write_with(path, |f| {
        f.write_all(bytes)?;
        Ok(())
    })
}

/// [`atomic_write`] over a caller-supplied fill function (streamed
/// writers). The file is fsynced after `fill` returns and only then
/// renamed over `path`.
pub fn atomic_write_with(
    path: &Path,
    fill: impl FnOnce(&mut std::fs::File) -> Result<()>,
) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    let tmp = tmp_path(path);
    let mut f = std::fs::File::create(&tmp)?;
    fill(&mut f)?;
    f.sync_all()?;
    drop(f);
    if let Some(fault) = chaos::hit_path(chaos::Site::AtomicWrite, path) {
        return chaos_atomic_write(path, &tmp, fault);
    }
    std::fs::rename(&tmp, path)?;
    Ok(())
}

/// Resolve an injected [`chaos::Site::AtomicWrite`] fault. `Fail`
/// aborts the write with a typed error (destination untouched, like a
/// failed rename); `Torn` simulates a crash mid-publish by leaving a
/// truncated destination behind; `BitFlip` corrupts the published
/// payload. Readers must catch the latter two via the CRC trailer.
#[cold]
fn chaos_atomic_write(path: &Path, tmp: &Path, fault: chaos::Fault) -> Result<()> {
    let mut bytes = std::fs::read(tmp)?;
    let _ = std::fs::remove_file(tmp);
    match chaos::apply_to_bytes(chaos::Site::AtomicWrite, fault, &mut bytes) {
        Some(msg) => Err(Error::Io(std::io::Error::new(
            std::io::ErrorKind::Other,
            format!("{msg} for {}", path.display()),
        ))),
        None => {
            // Mutated payload published non-atomically: exactly the torn
            // state a crash between sync and rename could leave behind.
            std::fs::write(path, &bytes)?;
            Ok(())
        }
    }
}

/// Fixed size of the `.pkd` header: magic (8) + dim (4) + n (8) +
/// has_truth (1).
pub const BIN_HEADER_BYTES: u64 = 21;

/// Parsed `.pkd` header — everything needed to stream the payload
/// without loading it (see [`probe_binary`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinHeader {
    /// Point dimensionality.
    pub dim: usize,
    /// Number of points in the payload.
    pub n: usize,
    /// Whether `n` i32 ground-truth labels follow the payload.
    pub has_truth: bool,
    /// Byte offset of the first payload row.
    pub payload_offset: u64,
}

impl BinHeader {
    /// Byte offset of row `i` (row-major f32 payload).
    pub fn row_offset(&self, i: usize) -> u64 {
        self.payload_offset + (i * self.dim * 4) as u64
    }

    /// Byte offset of the truth-label section (just past the payload).
    pub fn truth_offset(&self) -> u64 {
        self.row_offset(self.n)
    }
}

/// Read and validate a `.pkd` header without touching the payload —
/// the entry point for out-of-core streaming (O(1) memory regardless
/// of file size).
pub fn probe_binary(path: &Path) -> Result<BinHeader> {
    let mut r = std::fs::File::open(path)?;
    let mut head = [0u8; BIN_HEADER_BYTES as usize];
    r.read_exact(&mut head).map_err(|e| {
        data_err(path, format!("file too short for a dataset header: {e}"))
    })?;
    if &head[..8] != MAGIC {
        return Err(data_err(path, "not a parakmeans dataset (bad magic)".into()));
    }
    let dim = u32::from_le_bytes([head[8], head[9], head[10], head[11]]) as usize;
    let n_u64 = u64::from_le_bytes([
        head[12], head[13], head[14], head[15], head[16], head[17], head[18], head[19],
    ]);
    // validate in u64 BEFORE narrowing: on a 32-bit target an `as`
    // cast would truncate a lying header right past the guards below
    let n = usize::try_from(n_u64)
        .map_err(|_| data_err(path, format!("implausible header: n={n_u64}")))?;
    let has_truth = head[20] != 0;
    if dim == 0 {
        return Err(data_err(path, "header declares dim = 0".into()));
    }
    // implausible (n, dim) combinations would overflow the payload size
    // computation and panic on allocation — reject them as corrupt
    if n.checked_mul(dim).and_then(|v| v.checked_mul(4)).is_none() {
        return Err(data_err(path, format!("implausible header: n={n} dim={dim}")));
    }
    // the declared content must actually be on disk: catching a huge
    // (but representable) lying n here turns an attacker-sized
    // allocation or a mid-stream surprise into a typed error up front
    let file_len = r.metadata()?.len() as u128;
    let need = BIN_HEADER_BYTES as u128
        + n as u128 * dim as u128 * 4
        + if has_truth { n as u128 * 4 } else { 0 };
    if file_len < need {
        return Err(data_err(
            path,
            format!("truncated or corrupt: file is {file_len} B, header declares {need} B"),
        ));
    }
    Ok(BinHeader { dim, n, has_truth, payload_offset: BIN_HEADER_BYTES })
}

fn data_err(path: &Path, msg: String) -> Error {
    Error::Data(format!("{}: {msg}", path.display()))
}

/// Incremental `.pkd` writer: header up front, rows appended in chunks,
/// truth labels (if promised) on [`BinWriter::finish`]. Memory is
/// O(one chunk) — how `gen-data --chunk` synthesizes files larger than
/// RAM. [`write_binary`] is the whole-dataset convenience over this.
///
/// Writes stream to a `<name>.tmp` sibling; [`BinWriter::finish`]
/// appends a CRC32 trailer over every byte, fsyncs and renames — so a
/// crash mid-generation never leaves a torn `.pkd` under the final
/// name, and readers can detect any later corruption.
pub struct BinWriter {
    w: BufWriter<std::fs::File>,
    path: PathBuf,
    tmp: PathBuf,
    crc: Crc32,
    dim: usize,
    n: usize,
    has_truth: bool,
    rows_written: usize,
    truth_written: usize,
}

impl BinWriter {
    /// Create `path` (and parent dirs) and write the header for `n`
    /// points of `dim` coordinates.
    pub fn create(path: &Path, dim: usize, n: usize, has_truth: bool) -> Result<BinWriter> {
        if dim == 0 {
            return Err(Error::Shape("dim must be > 0".into()));
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = tmp_path(path);
        let w = BufWriter::new(std::fs::File::create(&tmp)?);
        let mut bw = BinWriter {
            w,
            path: path.to_path_buf(),
            tmp,
            crc: Crc32::new(),
            dim,
            n,
            has_truth,
            rows_written: 0,
            truth_written: 0,
        };
        bw.put(MAGIC)?;
        bw.put(&(dim as u32).to_le_bytes())?;
        bw.put(&(n as u64).to_le_bytes())?;
        bw.put(&[has_truth as u8])?;
        Ok(bw)
    }

    /// Write + hash (every payload byte feeds the CRC trailer).
    fn put(&mut self, bytes: &[u8]) -> Result<()> {
        self.w.write_all(bytes)?;
        self.crc.update(bytes);
        Ok(())
    }

    /// Append a row-major block of points (`rows.len() % dim == 0`).
    pub fn write_rows(&mut self, rows: &[f32]) -> Result<()> {
        if rows.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "block len {} not divisible by dim {}",
                rows.len(),
                self.dim
            )));
        }
        let nrows = rows.len() / self.dim;
        if self.rows_written + nrows > self.n {
            return Err(Error::Shape(format!(
                "writing {} rows past the declared n = {}",
                self.rows_written + nrows - self.n,
                self.n
            )));
        }
        for v in rows {
            self.put(&v.to_le_bytes())?;
        }
        self.rows_written += nrows;
        Ok(())
    }

    /// Append a block of truth labels (iff promised at creation). Only
    /// valid once all `n` rows are written — the truth section follows
    /// the payload on disk. Incremental, so label memory stays
    /// O(block) for streamed writes.
    pub fn write_truth(&mut self, labels: &[i32]) -> Result<()> {
        if !self.has_truth {
            return Err(Error::Shape("truth labels given but header says none".into()));
        }
        if self.rows_written != self.n {
            return Err(Error::Shape(format!(
                "truth written after only {} of {} rows",
                self.rows_written, self.n
            )));
        }
        if self.truth_written + labels.len() > self.n {
            return Err(Error::Shape(format!(
                "writing {} truth labels past the declared n = {}",
                self.truth_written + labels.len() - self.n,
                self.n
            )));
        }
        for t in labels {
            self.put(&t.to_le_bytes())?;
        }
        self.truth_written += labels.len();
        Ok(())
    }

    /// Write any remaining truth labels, append the CRC32 trailer,
    /// fsync and atomically rename into place. Errors if the row count
    /// or label count does not match the header (the temp file is left
    /// behind; the destination is never touched).
    pub fn finish(mut self, truth: Option<&[i32]>) -> Result<()> {
        if self.rows_written != self.n {
            return Err(Error::Shape(format!(
                "wrote {} rows, header declares {}",
                self.rows_written, self.n
            )));
        }
        if let Some(labels) = truth {
            self.write_truth(labels)?;
        }
        if self.has_truth && self.truth_written != self.n {
            return Err(Error::Shape(format!(
                "{} truth labels for {} points",
                self.truth_written, self.n
            )));
        }
        let trailer = self.crc.finish().to_le_bytes();
        self.w.write_all(&trailer)?;
        self.w.flush()?;
        self.w.get_ref().sync_all()?;
        std::fs::rename(&self.tmp, &self.path)?;
        Ok(())
    }
}

/// Write the binary format.
pub fn write_binary(path: &Path, ds: &Dataset) -> Result<()> {
    let mut w = BinWriter::create(path, ds.dim(), ds.len(), ds.truth.is_some())?;
    w.write_rows(ds.raw())?;
    w.finish(ds.truth.as_deref())
}

/// Read the binary format into memory. For files that must not be
/// loaded whole, stream via [`crate::data::source::FileSource`] instead.
///
/// Files written since the integrity retrofit carry a 4-byte CRC32
/// trailer which is verified incrementally (the hashing rides the
/// existing buffered read — no extra allocation). Legacy trailer-less
/// files still load, counted in [`artifact_warnings`]; any other
/// trailing length is a typed corruption error.
pub fn read_binary(path: &Path) -> Result<Dataset> {
    if let Some(fault) = chaos::hit_path(chaos::Site::ArtifactRead, path) {
        // The streaming reader has no byte buffer to mutate; every read
        // fault degrades to a typed failure here.
        let _ = fault;
        return Err(data_err(path, "chaos: injected artifact-read failure".into()));
    }
    let header = probe_binary(path)?;
    let need = BIN_HEADER_BYTES
        + (header.n as u64) * (header.dim as u64) * 4
        + if header.has_truth { header.n as u64 * 4 } else { 0 };
    // probe guaranteed file_len >= need
    let extra = std::fs::metadata(path)?.len().saturating_sub(need);
    if extra != 0 && extra != 4 {
        return Err(data_err(
            path,
            format!("{extra} unexpected trailing bytes after the declared content"),
        ));
    }
    let mut r = CrcReader::new(BufReader::new(std::fs::File::open(path)?));
    let mut skip = [0u8; BIN_HEADER_BYTES as usize];
    r.read_exact(&mut skip)?;

    let mut payload = vec![0u8; header.n * header.dim * 4];
    r.read_exact(&mut payload).map_err(|e| {
        data_err(
            path,
            format!(
                "truncated payload: header declares {} × {}D points ({e})",
                header.n, header.dim
            ),
        )
    })?;
    let mut data = Vec::with_capacity(header.n * header.dim);
    for c in payload.chunks_exact(4) {
        data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let mut ds = Dataset::from_vec(data, header.dim)?;
    if header.has_truth {
        let mut tbuf = vec![0u8; header.n * 4];
        r.read_exact(&mut tbuf).map_err(|e| {
            data_err(path, format!("truncated truth section: expected {} labels ({e})", header.n))
        })?;
        let truth: Vec<i32> = tbuf
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        ds.truth = Some(truth);
    }
    if extra == 4 {
        let computed = r.digest();
        let mut trail = [0u8; 4];
        r.read_exact(&mut trail)
            .map_err(|e| data_err(path, format!("truncated crc trailer: {e}")))?;
        let stored = u32::from_le_bytes(trail);
        if stored != computed {
            return Err(data_err(
                path,
                format!("crc mismatch: trailer {stored:#010x}, content {computed:#010x} — corrupt"),
            ));
        }
    } else {
        note_legacy_artifact();
    }
    Ok(ds)
}

// ---- trained-model persistence (.pkm) ----------------------------------

const MODEL_MAGIC: &[u8; 8] = b"PARAKMM1";

/// A trained K-Means model as persisted by `parakm run --save-model`
/// and loaded by `parakm serve --model` — centroids plus the training
/// provenance needed to trust them (DESIGN.md §7). Round-trips are
/// byte-exact on the centroid bits.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub k: usize,
    pub dim: usize,
    /// Seed the training run used.
    pub seed: u64,
    /// Engine name that produced the model (`"serial"`, `"dist"`, ...).
    pub engine: String,
    /// Lloyd iterations the training run executed.
    pub iterations: usize,
    /// Final training SSE.
    pub sse: f64,
    /// k×dim row-major centroids.
    pub centroids: Vec<f32>,
}

/// Encode a model to `.pkm` bytes: magic, k, dim, seed, engine string,
/// iterations, sse, the raw centroid bits (little-endian f32), then a
/// CRC32 trailer over everything before it.
pub fn encode_model(model: &Model) -> Result<Vec<u8>> {
    if model.k == 0 || model.dim == 0 {
        return Err(Error::Shape(format!("model: k {} × dim {} invalid", model.k, model.dim)));
    }
    if model.centroids.len() != model.k * model.dim {
        return Err(Error::Shape(format!(
            "model: centroids len {} != k {} × dim {}",
            model.centroids.len(),
            model.k,
            model.dim
        )));
    }
    let engine = model.engine.as_bytes();
    let mut out = Vec::with_capacity(48 + engine.len() + model.centroids.len() * 4 + 4);
    out.extend_from_slice(MODEL_MAGIC);
    out.extend_from_slice(&(model.k as u32).to_le_bytes());
    out.extend_from_slice(&(model.dim as u32).to_le_bytes());
    out.extend_from_slice(&model.seed.to_le_bytes());
    out.extend_from_slice(&(engine.len() as u32).to_le_bytes());
    out.extend_from_slice(engine);
    out.extend_from_slice(&(model.iterations as u64).to_le_bytes());
    out.extend_from_slice(&model.sse.to_bits().to_le_bytes());
    for v in &model.centroids {
        out.extend_from_slice(&v.to_le_bytes());
    }
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    Ok(out)
}

/// Write a `.pkm` model file atomically (temp file + fsync + rename)
/// with the CRC32 trailer of [`encode_model`].
pub fn write_model(path: &Path, model: &Model) -> Result<()> {
    atomic_write(path, &encode_model(model)?)
}

/// Bounds-checked little-endian cursor over untrusted bytes — the
/// shared primitive of [`decode_model`] and [`decode_ckpt`]. Every
/// read is guarded, so forged lengths become typed errors before any
/// allocation. `mkerr` picks the error variant (`Error::Data` for
/// `.pkm`, `Error::Ckpt` for `.pkc`).
struct Cur<'a> {
    b: &'a [u8],
    pos: usize,
    mkerr: fn(String) -> Error,
}

impl<'a> Cur<'a> {
    fn new(b: &'a [u8], mkerr: fn(String) -> Error) -> Cur<'a> {
        Cur { b, pos: 0, mkerr }
    }

    fn err(&self, m: String) -> Error {
        (self.mkerr)(m)
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.remaining() < n {
            return Err(self.err(format!(
                "truncated: {what} needs {n} bytes, {} left",
                self.remaining()
            )));
        }
        let s = &self.b[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self, what: &str) -> Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4, what)?.try_into().unwrap()))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8, what)?.try_into().unwrap()))
    }

    /// A `[len u32]` prefix for elements of `elem_bytes`, validated
    /// against the remaining input *before* any allocation.
    fn len_prefix(&mut self, elem_bytes: usize, what: &str) -> Result<usize> {
        let len = self.u32(what)? as usize;
        match len.checked_mul(elem_bytes) {
            Some(need) if need <= self.remaining() => Ok(len),
            _ => Err(self.err(format!(
                "forged length: {what} declares {len} elements, only {} bytes left",
                self.remaining()
            ))),
        }
    }
}

/// Decode `.pkm` bytes. Total over arbitrary input: corrupt, truncated
/// or trailing content is a typed [`Error::Data`], never a panic or an
/// attacker-sized allocation. Legacy trailer-less encodings still
/// decode, counted in [`artifact_warnings`].
pub fn decode_model(bytes: &[u8]) -> Result<Model> {
    let mut c = Cur::new(bytes, Error::Data);
    if c.take(8, "model magic")? != MODEL_MAGIC {
        return Err(Error::Data("not a parakmeans model (bad magic)".into()));
    }
    let k = c.u32("k")? as usize;
    let dim = c.u32("dim")? as usize;
    if k == 0 || dim == 0 || k.checked_mul(dim).and_then(|v| v.checked_mul(4)).is_none() {
        return Err(Error::Data(format!("implausible model header: k={k} dim={dim}")));
    }
    // the declared centroids must actually be present — same guard as
    // probe_binary, so a lying header is a typed error up front, never
    // an attacker-sized allocation
    let fixed = 8u128 + 4 + 4 + 8 + 4 + 8 + 8; // magic..engine_len + iters + sse
    if (bytes.len() as u128) < fixed + k as u128 * dim as u128 * 4 {
        return Err(Error::Data(format!(
            "truncated or corrupt: file is {} B, header declares k={k} dim={dim}",
            bytes.len()
        )));
    }
    let seed = c.u64("seed")?;
    let engine_len = c.u32("engine length")? as usize;
    if engine_len > 256 {
        return Err(Error::Data(format!("implausible engine-name length {engine_len}")));
    }
    let engine = String::from_utf8(c.take(engine_len, "engine name")?.to_vec())
        .map_err(|_| Error::Data("engine name is not valid utf-8".into()))?;
    let iterations = c.u64("iterations")? as usize;
    let sse = f64::from_bits(c.u64("sse")?);

    let payload = c
        .take(k * dim * 4, "centroids")
        .map_err(|_| Error::Data(format!("truncated centroids: header declares {k} × {dim}D")))?;
    let centroids: Vec<f32> =
        payload.chunks_exact(4).map(|ch| f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]])).collect();

    match c.remaining() {
        0 => note_legacy_artifact(),
        4 => {
            let end = c.pos;
            let computed = crc32(&bytes[..end]);
            let stored = c.u32("crc trailer")?;
            if stored != computed {
                return Err(Error::Data(format!(
                    "crc mismatch: trailer {stored:#010x}, content {computed:#010x} — corrupt"
                )));
            }
        }
        extra => {
            return Err(Error::Data(format!("{extra} trailing bytes after the centroid payload")));
        }
    }
    Ok(Model { k, dim, seed, engine, iterations, sse, centroids })
}

/// Read a `.pkm` model file; corrupt or truncated content is a typed
/// [`Error::Data`] naming the file.
pub fn read_model(path: &Path) -> Result<Model> {
    let mut bytes = std::fs::read(path)?;
    if let Some(fault) = chaos::hit_path(chaos::Site::ArtifactRead, path) {
        if let Some(msg) = chaos::apply_to_bytes(chaos::Site::ArtifactRead, fault, &mut bytes) {
            return Err(data_err(path, msg));
        }
        // Torn / bit-flipped bytes fall through to decode_model, whose
        // CRC trailer must reject them with a typed error.
    }
    decode_model(&bytes).map_err(|e| match e {
        Error::Data(m) => data_err(path, m),
        other => other,
    })
}

/// CSV header line for `dim` columns (`x0,x1,...`) — shared with the
/// CLI's streamed generator path so the two writers cannot drift.
pub fn csv_header(dim: usize) -> String {
    (0..dim).map(|j| format!("x{j}")).collect::<Vec<_>>().join(",")
}

/// One CSV data row (same formatting as [`write_csv`]).
pub fn csv_row(point: &[f32]) -> String {
    point.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
}

/// Write CSV (no truth labels; header `x0,x1,...`). Atomic like every
/// other artifact writer: temp file + fsync + rename.
pub fn write_csv(path: &Path, ds: &Dataset) -> Result<()> {
    atomic_write_with(path, |f| {
        let mut w = BufWriter::new(&mut *f);
        writeln!(w, "{}", csv_header(ds.dim()))?;
        for i in 0..ds.len() {
            writeln!(w, "{}", csv_row(ds.point(i)))?;
        }
        w.flush()?;
        Ok(())
    })
}

/// Read CSV produced by [`write_csv`] (or any numeric CSV with header).
///
/// Rejects ragged rows (cell count ≠ header width) and non-numeric or
/// non-finite cells with [`Error::Data`] naming the offending row — a
/// dataset with silent `NaN` points would poison every distance. The
/// cell-level strictness lives in
/// [`read_table_strict`](crate::util::csv::read_table_strict).
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let (header, rows) = crate::util::csv::read_table_strict(path).map_err(|e| match e {
        Error::Data(m) => data_err(path, m),
        other => other,
    })?;
    let dim = header.len();
    if dim == 0 {
        return Err(data_err(path, "csv has no columns".into()));
    }
    let mut data = Vec::with_capacity(rows.len() * dim);
    for (i, row) in rows.iter().enumerate() {
        if row.len() != dim {
            return Err(data_err(
                path,
                format!("csv row {i} has {} cells, expected {dim}", row.len()),
            ));
        }
        for (j, &v) in row.iter().enumerate() {
            // check after the f32 narrowing: a cell like 1e39 is
            // finite in f64 but saturates to inf as f32
            let f = v as f32;
            if !f.is_finite() {
                return Err(data_err(
                    path,
                    format!("csv row {i}, column {j}: non-numeric, non-finite or out-of-range"),
                ));
            }
            data.push(f);
        }
    }
    Dataset::from_vec(data, dim)
}

// ---- checkpoint codec (.pkc) -------------------------------------------

use crate::kmeans::ckpt::{Bounds, CkptState, Fingerprint};

const CKPT_MAGIC: &[u8; 8] = b"PARAKMC1";
const CKPT_VERSION: u32 = 1;

fn put_len(out: &mut Vec<u8>, len: usize) {
    out.extend_from_slice(&(len as u32).to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_len(out, s.len());
    out.extend_from_slice(s.as_bytes());
}

/// Append one CRC-framed section: `[len u32][payload][crc32 u32]`.
/// Each section carries its own checksum so a reader can tell *which*
/// part of a snapshot is damaged and a bit flip anywhere is caught.
fn put_section(out: &mut Vec<u8>, payload: &[u8]) {
    put_len(out, payload.len());
    out.extend_from_slice(payload);
    out.extend_from_slice(&crc32(payload).to_le_bytes());
}

/// Encode a checkpoint snapshot to `.pkc` bytes (DESIGN.md §14):
/// magic, format version, then three CRC-framed sections —
/// fingerprint (run identity + FNV hash), state (iteration, centroid
/// bits, convergence history) and bounds (empty payload for dense
/// engines). Every float is stored as its raw bits, so round-trips
/// are bit-exact including NaN history entries.
pub fn encode_ckpt(state: &CkptState) -> Vec<u8> {
    let fp = &state.fingerprint;
    let mut f = Vec::new();
    put_str(&mut f, &fp.engine);
    f.extend_from_slice(&fp.seed.to_le_bytes());
    f.extend_from_slice(&fp.k.to_le_bytes());
    put_str(&mut f, &fp.distance);
    put_str(&mut f, &fp.sched);
    f.extend_from_slice(&fp.n.to_le_bytes());
    f.extend_from_slice(&fp.d.to_le_bytes());
    f.extend_from_slice(&fp.hash().to_le_bytes());

    let mut s = Vec::new();
    s.extend_from_slice(&state.iteration.to_le_bytes());
    s.push(state.converged as u8);
    put_len(&mut s, state.centroids.len());
    for v in &state.centroids {
        s.extend_from_slice(&v.to_le_bytes());
    }
    put_len(&mut s, state.prev_centroids.len());
    for v in &state.prev_centroids {
        s.extend_from_slice(&v.to_le_bytes());
    }
    put_len(&mut s, state.history.len());
    for &(sse, shift) in &state.history {
        s.extend_from_slice(&sse.to_bits().to_le_bytes());
        s.extend_from_slice(&shift.to_bits().to_le_bytes());
    }
    put_len(&mut s, state.empty_events.len());
    for &e in &state.empty_events {
        s.extend_from_slice(&e.to_le_bytes());
    }

    let mut b = Vec::new();
    if let Some(bounds) = &state.bounds {
        put_len(&mut b, bounds.assign.len());
        for v in &bounds.assign {
            b.extend_from_slice(&v.to_le_bytes());
        }
        put_len(&mut b, bounds.upper.len());
        for v in &bounds.upper {
            b.extend_from_slice(&v.to_le_bytes());
        }
        put_len(&mut b, bounds.lower.len());
        for v in &bounds.lower {
            b.extend_from_slice(&v.to_le_bytes());
        }
        put_len(&mut b, bounds.sums.len());
        for v in &bounds.sums {
            b.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        put_len(&mut b, bounds.counts.len());
        for v in &bounds.counts {
            b.extend_from_slice(&v.to_le_bytes());
        }
        b.extend_from_slice(&bounds.prune_seed_computed.to_le_bytes());
        put_len(&mut b, bounds.prune_per_iter.len());
        for &(c, sk) in &bounds.prune_per_iter {
            b.extend_from_slice(&c.to_le_bytes());
            b.extend_from_slice(&sk.to_le_bytes());
        }
    }

    let mut out =
        Vec::with_capacity(12 + f.len() + s.len() + b.len() + 24);
    out.extend_from_slice(CKPT_MAGIC);
    out.extend_from_slice(&CKPT_VERSION.to_le_bytes());
    put_section(&mut out, &f);
    put_section(&mut out, &s);
    put_section(&mut out, &b);
    out
}

/// Pull one `[len][payload][crc]` section, verifying the checksum.
fn take_section<'a>(c: &mut Cur<'a>, what: &str) -> Result<&'a [u8]> {
    let len = c.len_prefix(1, what)?;
    let payload = c.take(len, what)?;
    let stored = c.u32(what)?;
    let computed = crc32(payload);
    if stored != computed {
        return Err(Error::Ckpt(format!(
            "crc mismatch in {what}: trailer {stored:#010x}, content {computed:#010x} — corrupt"
        )));
    }
    Ok(payload)
}

fn ckpt_str(c: &mut Cur<'_>, what: &str) -> Result<String> {
    let len = c.len_prefix(1, what)?;
    if len > 256 {
        return Err(c.err(format!("implausible {what} length {len}")));
    }
    String::from_utf8(c.take(len, what)?.to_vec())
        .map_err(|_| Error::Ckpt(format!("{what} is not valid utf-8")))
}

fn ckpt_f32s(c: &mut Cur<'_>, what: &str) -> Result<Vec<f32>> {
    let len = c.len_prefix(4, what)?;
    let raw = c.take(len * 4, what)?;
    Ok(raw.chunks_exact(4).map(|ch| f32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]])).collect())
}

fn ckpt_i32s(c: &mut Cur<'_>, what: &str) -> Result<Vec<i32>> {
    let len = c.len_prefix(4, what)?;
    let raw = c.take(len * 4, what)?;
    Ok(raw.chunks_exact(4).map(|ch| i32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]])).collect())
}

fn ckpt_u64s(c: &mut Cur<'_>, what: &str) -> Result<Vec<u64>> {
    let len = c.len_prefix(8, what)?;
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(c.u64(what)?);
    }
    Ok(out)
}

fn ckpt_f64s(c: &mut Cur<'_>, what: &str) -> Result<Vec<f64>> {
    let len = c.len_prefix(8, what)?;
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push(f64::from_bits(c.u64(what)?));
    }
    Ok(out)
}

fn ckpt_u64_pairs(c: &mut Cur<'_>, what: &str) -> Result<Vec<(u64, u64)>> {
    let len = c.len_prefix(16, what)?;
    let mut out = Vec::with_capacity(len);
    for _ in 0..len {
        out.push((c.u64(what)?, c.u64(what)?));
    }
    Ok(out)
}

fn decode_ckpt_fingerprint(payload: &[u8]) -> Result<Fingerprint> {
    let mut c = Cur::new(payload, Error::Ckpt);
    let engine = ckpt_str(&mut c, "fingerprint engine")?;
    let seed = c.u64("fingerprint seed")?;
    let k = c.u32("fingerprint k")?;
    let distance = ckpt_str(&mut c, "fingerprint distance")?;
    let sched = ckpt_str(&mut c, "fingerprint sched")?;
    let n = c.u64("fingerprint n")?;
    let d = c.u32("fingerprint d")?;
    let stored_hash = c.u64("fingerprint hash")?;
    if c.remaining() != 0 {
        return Err(Error::Ckpt(format!(
            "{} trailing bytes in the fingerprint section",
            c.remaining()
        )));
    }
    let fp = Fingerprint { engine, seed, k, distance, sched, n, d };
    if fp.hash() != stored_hash {
        return Err(Error::Ckpt(
            "fingerprint hash does not match its fields — forged or corrupt".into(),
        ));
    }
    Ok(fp)
}

/// Decode `.pkc` bytes. Total over arbitrary input: truncation at any
/// byte, bit flips, forged section lengths and wrong versions are all
/// typed [`Error::Ckpt`] — never a panic or an attacker-sized
/// allocation (fuzz-pinned in `tests/fuzz_artifacts.rs`).
pub fn decode_ckpt(bytes: &[u8]) -> Result<CkptState> {
    let mut c = Cur::new(bytes, Error::Ckpt);
    if c.take(8, "checkpoint magic")? != CKPT_MAGIC {
        return Err(Error::Ckpt("not a parakmeans checkpoint (bad magic)".into()));
    }
    let version = c.u32("format version")?;
    if version != CKPT_VERSION {
        return Err(Error::Ckpt(format!(
            "unsupported checkpoint version {version} (this build reads {CKPT_VERSION})"
        )));
    }
    let fp_payload = take_section(&mut c, "fingerprint section")?;
    let st_payload = take_section(&mut c, "state section")?;
    let bd_payload = take_section(&mut c, "bounds section")?;
    if c.remaining() != 0 {
        return Err(Error::Ckpt(format!(
            "{} trailing bytes after the bounds section",
            c.remaining()
        )));
    }

    let fingerprint = decode_ckpt_fingerprint(fp_payload)?;

    let mut s = Cur::new(st_payload, Error::Ckpt);
    let iteration = s.u64("state iteration")?;
    let converged = match s.u8("state converged flag")? {
        0 => false,
        1 => true,
        v => return Err(Error::Ckpt(format!("state converged flag is {v}, not 0/1"))),
    };
    let centroids = ckpt_f32s(&mut s, "state centroids")?;
    let prev_centroids = ckpt_f32s(&mut s, "state prev_centroids")?;
    let hist_len = s.len_prefix(16, "state history")?;
    let mut history = Vec::with_capacity(hist_len);
    for _ in 0..hist_len {
        let sse = f64::from_bits(s.u64("state history sse")?);
        let shift = f64::from_bits(s.u64("state history shift")?);
        history.push((sse, shift));
    }
    let empty_events = ckpt_u64s(&mut s, "state empty_events")?;
    if s.remaining() != 0 {
        return Err(Error::Ckpt(format!(
            "{} trailing bytes in the state section",
            s.remaining()
        )));
    }

    let bounds = if bd_payload.is_empty() {
        None
    } else {
        let mut b = Cur::new(bd_payload, Error::Ckpt);
        let assign = ckpt_i32s(&mut b, "bounds assign")?;
        let upper = ckpt_f32s(&mut b, "bounds upper")?;
        let lower = ckpt_f32s(&mut b, "bounds lower")?;
        let sums = ckpt_f64s(&mut b, "bounds sums")?;
        let counts = ckpt_u64s(&mut b, "bounds counts")?;
        let prune_seed_computed = b.u64("bounds prune seed")?;
        let prune_per_iter = ckpt_u64_pairs(&mut b, "bounds prune rows")?;
        if b.remaining() != 0 {
            return Err(Error::Ckpt(format!(
                "{} trailing bytes in the bounds section",
                b.remaining()
            )));
        }
        Some(Bounds { assign, upper, lower, sums, counts, prune_seed_computed, prune_per_iter })
    };

    Ok(CkptState {
        fingerprint,
        iteration,
        converged,
        centroids,
        prev_centroids,
        history,
        empty_events,
        bounds,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip_with_truth() {
        let ds = MixtureSpec::paper_2d(4).generate(257, 3);
        let p = tmp("rt.pkd");
        write_binary(&p, &ds).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(back.truth.is_some());
    }

    #[test]
    fn binary_roundtrip_without_truth() {
        let mut ds = MixtureSpec::paper_3d(4).generate(64, 3);
        ds.truth = None;
        let p = tmp("rt2.pkd");
        write_binary(&p, &ds).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(back.truth.is_none());
    }

    #[test]
    fn probe_reads_header_without_payload() {
        let ds = MixtureSpec::paper_3d(4).generate(1234, 7);
        let p = tmp("probe.pkd");
        write_binary(&p, &ds).unwrap();
        let h = probe_binary(&p).unwrap();
        assert_eq!(h.dim, 3);
        assert_eq!(h.n, 1234);
        assert!(h.has_truth);
        assert_eq!(h.payload_offset, BIN_HEADER_BYTES);
        assert_eq!(h.row_offset(10), BIN_HEADER_BYTES + 120);
        assert_eq!(h.truth_offset(), BIN_HEADER_BYTES + 1234 * 12);
    }

    #[test]
    fn rejects_bad_magic_typed() {
        let p = tmp("bad.pkd");
        std::fs::write(&p, b"NOTMAGIC123456789012345").unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_short_header_typed() {
        let p = tmp("short.pkd");
        std::fs::write(&p, b"PARA").unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
    }

    #[test]
    fn rejects_zero_dim_header() {
        let p = tmp("zdim.pkd");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
    }

    #[test]
    fn rejects_implausible_header() {
        let p = tmp("huge.pkd");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("implausible"), "{err}");
    }

    #[test]
    fn rejects_lying_header_before_allocation() {
        // representable but false n: the declared payload must be on
        // disk, or probe fails typed instead of read_binary attempting
        // a header-sized allocation
        let p = tmp("liar.pkd");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated or corrupt"), "{err}");
    }

    fn sample_model() -> Model {
        Model {
            k: 3,
            dim: 2,
            seed: 42,
            engine: "dist".into(),
            iterations: 17,
            sse: 123.456789,
            // awkward bit patterns: -0.0, subnormal, almost-1
            centroids: vec![-0.0, f32::MIN_POSITIVE, 1.0000001, -5.25, 1e-30, 9.75],
        }
    }

    #[test]
    fn model_roundtrip_is_byte_exact_on_centroids() {
        let m = sample_model();
        let p = tmp("model_rt.pkm");
        write_model(&p, &m).unwrap();
        let back = read_model(&p).unwrap();
        assert_eq!(back.k, m.k);
        assert_eq!(back.dim, m.dim);
        assert_eq!(back.seed, m.seed);
        assert_eq!(back.engine, m.engine);
        assert_eq!(back.iterations, m.iterations);
        assert_eq!(back.sse.to_bits(), m.sse.to_bits());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.centroids), bits(&m.centroids));
    }

    #[test]
    fn model_write_validates_shape() {
        let p = tmp("model_bad.pkm");
        let mut m = sample_model();
        m.centroids.pop();
        assert!(matches!(write_model(&p, &m).unwrap_err(), Error::Shape(_)));
        let mut m = sample_model();
        m.k = 0;
        m.centroids.clear();
        assert!(matches!(write_model(&p, &m).unwrap_err(), Error::Shape(_)));
    }

    #[test]
    fn model_corruption_is_typed() {
        let p = tmp("model_corrupt.pkm");
        write_model(&p, &sample_model()).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        std::fs::write(&p, &bad).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("bad magic"), "{err}");

        // truncated centroids
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");

        // lying header: a representable but false k×dim on a tiny file
        // must be a typed error BEFORE any allocation
        let mut lying = bytes.clone();
        lying[8..12].copy_from_slice(&u32::MAX.to_le_bytes()); // k
        lying[12..16].copy_from_slice(&(1u32 << 16).to_le_bytes()); // dim
        std::fs::write(&p, &lying).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated or corrupt"), "{err}");

        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        std::fs::write(&p, &long).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn csv_roundtrip() {
        let mut ds = MixtureSpec::paper_2d(4).generate(100, 9);
        ds.truth = None;
        let p = tmp("rt.csv");
        write_csv(&p, &ds).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.dim(), 2);
        assert_eq!(back.len(), 100);
        for i in 0..100 {
            for j in 0..2 {
                assert!((back.point(i)[j] - ds.point(i)[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn truncated_binary_errors_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(64, 3);
        let p = tmp("trunc.pkd");
        write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn truncated_truth_section_errors_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(64, 3);
        let p = tmp("trunc_truth.pkd");
        write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // keep the payload intact, cut the truth labels short
        let keep = BIN_HEADER_BYTES as usize + 64 * 2 * 4 + 10;
        std::fs::write(&p, &bytes[..keep]).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn ragged_csv_row_errors_typed() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "x0,x1\n1.0,2.0\n3.0\n").unwrap();
        let err = read_csv(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("row 1"), "{err}");
    }

    #[test]
    fn non_numeric_csv_cell_errors_typed() {
        let p = tmp("garbage.csv");
        std::fs::write(&p, "x0,x1\n1.0,2.0\n3.0,banana\n").unwrap();
        let err = read_csv(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("row 1"), "{err}");
    }

    #[test]
    fn f32_overflowing_csv_cell_errors_typed() {
        // finite in f64, +inf after the f32 narrowing — must not pass
        let p = tmp("overflow.csv");
        std::fs::write(&p, "x0,x1\n1.0,2.0\n3.0,1e39\n").unwrap();
        let err = read_csv(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("row 1, column 1"), "{err}");
    }

    #[test]
    fn bin_writer_streams_in_chunks() {
        let ds = MixtureSpec::paper_3d(4).generate(301, 5);
        let p = tmp("chunked.pkd");
        let mut w = BinWriter::create(&p, 3, 301, true).unwrap();
        // ragged chunking: 100 + 100 + 101 rows
        w.write_rows(ds.rows(0, 100)).unwrap();
        w.write_rows(ds.rows(100, 200)).unwrap();
        w.write_rows(ds.rows(200, 301)).unwrap();
        w.finish(ds.truth.as_deref()).unwrap();
        // byte-identical to the whole-dataset writer
        let p2 = tmp("whole.pkd");
        write_binary(&p2, &ds).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn bin_writer_incremental_truth_matches_one_shot() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 7);
        let truth = ds.truth.clone().unwrap();
        let one_shot = tmp("truth_oneshot.pkd");
        write_binary(&one_shot, &ds).unwrap();

        let streamed = tmp("truth_streamed.pkd");
        let mut w = BinWriter::create(&streamed, 2, 100, true).unwrap();
        w.write_rows(ds.raw()).unwrap();
        w.write_truth(&truth[..40]).unwrap();
        w.write_truth(&truth[40..]).unwrap();
        w.finish(None).unwrap();
        assert_eq!(std::fs::read(&one_shot).unwrap(), std::fs::read(&streamed).unwrap());

        // truth before the payload completes is rejected
        let mut w = BinWriter::create(&tmp("early.pkd"), 2, 2, true).unwrap();
        assert!(w.write_truth(&[0]).is_err());
        // overrunning the label count is rejected
        let mut w = BinWriter::create(&tmp("over.pkd"), 2, 1, true).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        assert!(w.write_truth(&[0, 1]).is_err());
    }

    #[test]
    fn bin_writer_validates_counts() {
        let p = tmp("wv.pkd");
        let mut w = BinWriter::create(&p, 2, 3, false).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        assert!(w.write_rows(&[1.0, 2.0, 3.0]).is_err()); // ragged block
        assert!(w.write_rows(&[0.0; 8]).is_err()); // past declared n
        assert!(w.finish(None).is_err()); // short: 1 of 3 rows written

        let mut w = BinWriter::create(&p, 2, 1, false).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        assert!(w.finish(Some(&[0])).is_err()); // unpromised truth
    }

    #[test]
    fn atomic_write_replaces_via_rename() {
        let p = tmp("atomic.txt");
        atomic_write(&p, b"first").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"first");
        atomic_write(&p, b"second").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"second");
        // no temp residue after a clean write
        assert!(!tmp_path(&p).exists());
    }

    #[test]
    fn atomic_write_failure_leaves_destination_untouched() {
        let p = tmp("atomic_fail.txt");
        atomic_write(&p, b"good").unwrap();
        // injected mid-fill failure: destination keeps the old bytes,
        // only the temp sibling may be left behind
        let err = atomic_write_with(&p, |f| {
            f.write_all(b"half-written")?;
            Err(Error::Data("injected crash".into()))
        })
        .unwrap_err();
        assert!(err.to_string().contains("injected crash"), "{err}");
        assert_eq!(std::fs::read(&p).unwrap(), b"good");
        assert!(tmp_path(&p).exists(), "failed write leaves its temp file for inspection");
        // the next write overwrites the stale temp and succeeds
        atomic_write(&p, b"recovered").unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), b"recovered");
        assert!(!tmp_path(&p).exists());
    }

    #[test]
    fn bin_writer_is_atomic_until_finish() {
        let p = tmp("atomic_bin.pkd");
        let _ = std::fs::remove_file(&p);
        let mut w = BinWriter::create(&p, 2, 2, false).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        // mid-stream: final name absent, temp present
        assert!(!p.exists());
        assert!(tmp_path(&p).exists());
        w.write_rows(&[3.0, 4.0]).unwrap();
        w.finish(None).unwrap();
        assert!(p.exists());
        assert!(!tmp_path(&p).exists());
    }

    #[test]
    fn legacy_crcless_pkd_loads_with_warning() {
        let ds = MixtureSpec::paper_2d(4).generate(32, 3);
        let p = tmp("legacy.pkd");
        write_binary(&p, &ds).unwrap();
        // fabricate a pre-retrofit file by stripping the trailer
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        let before = artifact_warnings();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(artifact_warnings() > before, "legacy read must be counted");
    }

    #[test]
    fn corrupt_pkd_payload_fails_crc_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(32, 3);
        let p = tmp("bitrot.pkd");
        write_binary(&p, &ds).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        // flip one payload bit — sizes all still line up, only the
        // checksum can catch it
        let mid = BIN_HEADER_BYTES as usize + 17;
        bytes[mid] ^= 0x40;
        std::fs::write(&p, &bytes).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err:?}");
        assert!(err.to_string().contains("crc mismatch"), "{err}");
    }

    #[test]
    fn legacy_crcless_pkm_loads_with_warning_and_bitrot_is_caught() {
        let p = tmp("legacy.pkm");
        write_model(&p, &sample_model()).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        std::fs::write(&p, &bytes[..bytes.len() - 4]).unwrap();
        let before = artifact_warnings();
        assert_eq!(read_model(&p).unwrap(), sample_model());
        assert!(artifact_warnings() > before);

        let mut rot = bytes.clone();
        let last = rot.len() - 6; // inside the centroid payload
        rot[last] ^= 0x01;
        std::fs::write(&p, &rot).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(err.to_string().contains("crc mismatch"), "{err}");
    }

    fn sample_ckpt(bounds: bool) -> CkptState {
        CkptState {
            fingerprint: Fingerprint {
                engine: "elkan".into(),
                seed: 7,
                k: 2,
                distance: "exact".into(),
                sched: "static".into(),
                n: 3,
                d: 2,
            },
            iteration: 2,
            converged: false,
            centroids: vec![-0.0, 1.5, f32::MIN_POSITIVE, 2.0],
            prev_centroids: vec![0.0, 1.0, 2.0, 3.0],
            // NaN sse entries must round-trip bit-exact
            history: vec![(f64::NAN, 0.5), (12.25, 1e-9)],
            empty_events: vec![0, 1],
            bounds: bounds.then(|| Bounds {
                assign: vec![0, 1, 1],
                upper: vec![0.1, 0.2, 0.3],
                lower: vec![1.0; 6],
                sums: vec![0.5f64; 4],
                counts: vec![1, 2],
                prune_seed_computed: 6,
                prune_per_iter: vec![(4, 2), (3, 3)],
            }),
        }
    }

    fn bits_eq(a: &CkptState, b: &CkptState) {
        assert_eq!(a.fingerprint, b.fingerprint);
        assert_eq!(a.iteration, b.iteration);
        assert_eq!(a.converged, b.converged);
        let f32bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(f32bits(&a.centroids), f32bits(&b.centroids));
        assert_eq!(f32bits(&a.prev_centroids), f32bits(&b.prev_centroids));
        let histbits = |h: &[(f64, f64)]| {
            h.iter().map(|&(s, e)| (s.to_bits(), e.to_bits())).collect::<Vec<_>>()
        };
        assert_eq!(histbits(&a.history), histbits(&b.history));
        assert_eq!(a.empty_events, b.empty_events);
        match (&a.bounds, &b.bounds) {
            (None, None) => {}
            (Some(x), Some(y)) => {
                assert_eq!(x.assign, y.assign);
                assert_eq!(f32bits(&x.upper), f32bits(&y.upper));
                assert_eq!(f32bits(&x.lower), f32bits(&y.lower));
                let f64bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(f64bits(&x.sums), f64bits(&y.sums));
                assert_eq!(x.counts, y.counts);
                assert_eq!(x.prune_seed_computed, y.prune_seed_computed);
                assert_eq!(x.prune_per_iter, y.prune_per_iter);
            }
            _ => panic!("bounds presence differs"),
        }
    }

    #[test]
    fn ckpt_roundtrip_is_bit_exact() {
        for bounds in [false, true] {
            let s = sample_ckpt(bounds);
            let back = decode_ckpt(&encode_ckpt(&s)).unwrap();
            bits_eq(&s, &back);
        }
    }

    #[test]
    fn ckpt_corruption_is_typed() {
        let bytes = encode_ckpt(&sample_ckpt(true));

        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        let err = decode_ckpt(&bad).unwrap_err();
        assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
        assert!(err.to_string().contains("bad magic"), "{err}");

        // wrong version
        let mut bad = bytes.clone();
        bad[8..12].copy_from_slice(&99u32.to_le_bytes());
        let err = decode_ckpt(&bad).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");

        // a flipped bit anywhere in a section payload fails its CRC
        let mut bad = bytes.clone();
        let mid = bytes.len() / 2;
        bad[mid] ^= 0x10;
        assert!(matches!(decode_ckpt(&bad).unwrap_err(), Error::Ckpt(_)));

        // truncation at any prefix is typed, never a panic
        for cut in [0, 7, 11, 12, 20, bytes.len() - 1] {
            assert!(matches!(decode_ckpt(&bytes[..cut]).unwrap_err(), Error::Ckpt(_)));
        }

        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        let err = decode_ckpt(&long).unwrap_err();
        assert!(err.to_string().contains("trailing"), "{err}");
    }
}
