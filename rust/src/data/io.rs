//! Dataset (de)serialization.
//!
//! Two formats:
//! - **binary** (`.pkd`): little-endian, magic + dim + n + f32 payload
//!   (+ optional truth labels). Fast path used by the CLI `gen-data` /
//!   `run` round trip for the 1M-point workloads, and the format the
//!   out-of-core [`crate::data::source::FileSource`] streams from.
//! - **CSV**: one point per row, interchange with external tools.
//!
//! All readers return typed errors (DESIGN.md §8 error taxonomy):
//! [`Error::Data`] for content that is present but wrong (bad magic,
//! truncated payload, ragged or non-numeric CSV rows), [`Error::Io`]
//! only when the OS itself fails to read.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::Dataset;
use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"PARAKMD1";

/// Fixed size of the `.pkd` header: magic (8) + dim (4) + n (8) +
/// has_truth (1).
pub const BIN_HEADER_BYTES: u64 = 21;

/// Parsed `.pkd` header — everything needed to stream the payload
/// without loading it (see [`probe_binary`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BinHeader {
    /// Point dimensionality.
    pub dim: usize,
    /// Number of points in the payload.
    pub n: usize,
    /// Whether `n` i32 ground-truth labels follow the payload.
    pub has_truth: bool,
    /// Byte offset of the first payload row.
    pub payload_offset: u64,
}

impl BinHeader {
    /// Byte offset of row `i` (row-major f32 payload).
    pub fn row_offset(&self, i: usize) -> u64 {
        self.payload_offset + (i * self.dim * 4) as u64
    }

    /// Byte offset of the truth-label section (just past the payload).
    pub fn truth_offset(&self) -> u64 {
        self.row_offset(self.n)
    }
}

/// Read and validate a `.pkd` header without touching the payload —
/// the entry point for out-of-core streaming (O(1) memory regardless
/// of file size).
pub fn probe_binary(path: &Path) -> Result<BinHeader> {
    let mut r = std::fs::File::open(path)?;
    let mut head = [0u8; BIN_HEADER_BYTES as usize];
    r.read_exact(&mut head).map_err(|e| {
        data_err(path, format!("file too short for a dataset header: {e}"))
    })?;
    if &head[..8] != MAGIC {
        return Err(data_err(path, "not a parakmeans dataset (bad magic)".into()));
    }
    let dim = u32::from_le_bytes([head[8], head[9], head[10], head[11]]) as usize;
    let n_u64 = u64::from_le_bytes([
        head[12], head[13], head[14], head[15], head[16], head[17], head[18], head[19],
    ]);
    // validate in u64 BEFORE narrowing: on a 32-bit target an `as`
    // cast would truncate a lying header right past the guards below
    let n = usize::try_from(n_u64)
        .map_err(|_| data_err(path, format!("implausible header: n={n_u64}")))?;
    let has_truth = head[20] != 0;
    if dim == 0 {
        return Err(data_err(path, "header declares dim = 0".into()));
    }
    // implausible (n, dim) combinations would overflow the payload size
    // computation and panic on allocation — reject them as corrupt
    if n.checked_mul(dim).and_then(|v| v.checked_mul(4)).is_none() {
        return Err(data_err(path, format!("implausible header: n={n} dim={dim}")));
    }
    // the declared content must actually be on disk: catching a huge
    // (but representable) lying n here turns an attacker-sized
    // allocation or a mid-stream surprise into a typed error up front
    let file_len = r.metadata()?.len() as u128;
    let need = BIN_HEADER_BYTES as u128
        + n as u128 * dim as u128 * 4
        + if has_truth { n as u128 * 4 } else { 0 };
    if file_len < need {
        return Err(data_err(
            path,
            format!("truncated or corrupt: file is {file_len} B, header declares {need} B"),
        ));
    }
    Ok(BinHeader { dim, n, has_truth, payload_offset: BIN_HEADER_BYTES })
}

fn data_err(path: &Path, msg: String) -> Error {
    Error::Data(format!("{}: {msg}", path.display()))
}

/// Incremental `.pkd` writer: header up front, rows appended in chunks,
/// truth labels (if promised) on [`BinWriter::finish`]. Memory is
/// O(one chunk) — how `gen-data --chunk` synthesizes files larger than
/// RAM. [`write_binary`] is the whole-dataset convenience over this.
pub struct BinWriter {
    w: BufWriter<std::fs::File>,
    dim: usize,
    n: usize,
    has_truth: bool,
    rows_written: usize,
    truth_written: usize,
}

impl BinWriter {
    /// Create `path` (and parent dirs) and write the header for `n`
    /// points of `dim` coordinates.
    pub fn create(path: &Path, dim: usize, n: usize, has_truth: bool) -> Result<BinWriter> {
        if dim == 0 {
            return Err(Error::Shape("dim must be > 0".into()));
        }
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut w = BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&(dim as u32).to_le_bytes())?;
        w.write_all(&(n as u64).to_le_bytes())?;
        w.write_all(&[has_truth as u8])?;
        Ok(BinWriter { w, dim, n, has_truth, rows_written: 0, truth_written: 0 })
    }

    /// Append a row-major block of points (`rows.len() % dim == 0`).
    pub fn write_rows(&mut self, rows: &[f32]) -> Result<()> {
        if rows.len() % self.dim != 0 {
            return Err(Error::Shape(format!(
                "block len {} not divisible by dim {}",
                rows.len(),
                self.dim
            )));
        }
        let nrows = rows.len() / self.dim;
        if self.rows_written + nrows > self.n {
            return Err(Error::Shape(format!(
                "writing {} rows past the declared n = {}",
                self.rows_written + nrows - self.n,
                self.n
            )));
        }
        for v in rows {
            self.w.write_all(&v.to_le_bytes())?;
        }
        self.rows_written += nrows;
        Ok(())
    }

    /// Append a block of truth labels (iff promised at creation). Only
    /// valid once all `n` rows are written — the truth section follows
    /// the payload on disk. Incremental, so label memory stays
    /// O(block) for streamed writes.
    pub fn write_truth(&mut self, labels: &[i32]) -> Result<()> {
        if !self.has_truth {
            return Err(Error::Shape("truth labels given but header says none".into()));
        }
        if self.rows_written != self.n {
            return Err(Error::Shape(format!(
                "truth written after only {} of {} rows",
                self.rows_written, self.n
            )));
        }
        if self.truth_written + labels.len() > self.n {
            return Err(Error::Shape(format!(
                "writing {} truth labels past the declared n = {}",
                self.truth_written + labels.len() - self.n,
                self.n
            )));
        }
        for t in labels {
            self.w.write_all(&t.to_le_bytes())?;
        }
        self.truth_written += labels.len();
        Ok(())
    }

    /// Write any remaining truth labels and flush. Errors if the row
    /// count or label count does not match the header.
    pub fn finish(mut self, truth: Option<&[i32]>) -> Result<()> {
        if self.rows_written != self.n {
            return Err(Error::Shape(format!(
                "wrote {} rows, header declares {}",
                self.rows_written, self.n
            )));
        }
        if let Some(labels) = truth {
            self.write_truth(labels)?;
        }
        if self.has_truth && self.truth_written != self.n {
            return Err(Error::Shape(format!(
                "{} truth labels for {} points",
                self.truth_written, self.n
            )));
        }
        self.w.flush()?;
        Ok(())
    }
}

/// Write the binary format.
pub fn write_binary(path: &Path, ds: &Dataset) -> Result<()> {
    let mut w = BinWriter::create(path, ds.dim(), ds.len(), ds.truth.is_some())?;
    w.write_rows(ds.raw())?;
    w.finish(ds.truth.as_deref())
}

/// Read the binary format into memory. For files that must not be
/// loaded whole, stream via [`crate::data::source::FileSource`] instead.
pub fn read_binary(path: &Path) -> Result<Dataset> {
    let header = probe_binary(path)?;
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut skip = [0u8; BIN_HEADER_BYTES as usize];
    r.read_exact(&mut skip)?;

    let mut payload = vec![0u8; header.n * header.dim * 4];
    r.read_exact(&mut payload).map_err(|e| {
        data_err(
            path,
            format!(
                "truncated payload: header declares {} × {}D points ({e})",
                header.n, header.dim
            ),
        )
    })?;
    let mut data = Vec::with_capacity(header.n * header.dim);
    for c in payload.chunks_exact(4) {
        data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let mut ds = Dataset::from_vec(data, header.dim)?;
    if header.has_truth {
        let mut tbuf = vec![0u8; header.n * 4];
        r.read_exact(&mut tbuf).map_err(|e| {
            data_err(path, format!("truncated truth section: expected {} labels ({e})", header.n))
        })?;
        let truth: Vec<i32> = tbuf
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        ds.truth = Some(truth);
    }
    Ok(ds)
}

// ---- trained-model persistence (.pkm) ----------------------------------

const MODEL_MAGIC: &[u8; 8] = b"PARAKMM1";

/// A trained K-Means model as persisted by `parakm run --save-model`
/// and loaded by `parakm serve --model` — centroids plus the training
/// provenance needed to trust them (DESIGN.md §7). Round-trips are
/// byte-exact on the centroid bits.
#[derive(Debug, Clone, PartialEq)]
pub struct Model {
    pub k: usize,
    pub dim: usize,
    /// Seed the training run used.
    pub seed: u64,
    /// Engine name that produced the model (`"serial"`, `"dist"`, ...).
    pub engine: String,
    /// Lloyd iterations the training run executed.
    pub iterations: usize,
    /// Final training SSE.
    pub sse: f64,
    /// k×dim row-major centroids.
    pub centroids: Vec<f32>,
}

/// Write a `.pkm` model file: magic, k, dim, seed, engine string,
/// iterations, sse, then the raw centroid bits (little-endian f32).
pub fn write_model(path: &Path, model: &Model) -> Result<()> {
    if model.k == 0 || model.dim == 0 {
        return Err(Error::Shape(format!("model: k {} × dim {} invalid", model.k, model.dim)));
    }
    if model.centroids.len() != model.k * model.dim {
        return Err(Error::Shape(format!(
            "model: centroids len {} != k {} × dim {}",
            model.centroids.len(),
            model.k,
            model.dim
        )));
    }
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MODEL_MAGIC)?;
    w.write_all(&(model.k as u32).to_le_bytes())?;
    w.write_all(&(model.dim as u32).to_le_bytes())?;
    w.write_all(&model.seed.to_le_bytes())?;
    let engine = model.engine.as_bytes();
    w.write_all(&(engine.len() as u32).to_le_bytes())?;
    w.write_all(engine)?;
    w.write_all(&(model.iterations as u64).to_le_bytes())?;
    w.write_all(&model.sse.to_bits().to_le_bytes())?;
    for v in &model.centroids {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read a `.pkm` model file; corrupt or truncated content is a typed
/// [`Error::Data`] naming the file.
pub fn read_model(path: &Path) -> Result<Model> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let short = |e: std::io::Error| data_err(path, format!("truncated model file: {e}"));

    let mut magic = [0u8; 8];
    r.read_exact(&mut magic).map_err(short)?;
    if &magic != MODEL_MAGIC {
        return Err(data_err(path, "not a parakmeans model (bad magic)".into()));
    }
    let mut b4 = [0u8; 4];
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b4).map_err(short)?;
    let k = u32::from_le_bytes(b4) as usize;
    r.read_exact(&mut b4).map_err(short)?;
    let dim = u32::from_le_bytes(b4) as usize;
    if k == 0 || dim == 0 || k.checked_mul(dim).and_then(|v| v.checked_mul(4)).is_none() {
        return Err(data_err(path, format!("implausible model header: k={k} dim={dim}")));
    }
    // the declared centroids must actually be on disk — same guard as
    // probe_binary, so a lying header is a typed error up front, never
    // an attacker-sized allocation
    let file_len = std::fs::metadata(path)?.len() as u128;
    let fixed = 8u128 + 4 + 4 + 8 + 4 + 8 + 8; // magic..engine_len + iters + sse
    if file_len < fixed + k as u128 * dim as u128 * 4 {
        return Err(data_err(
            path,
            format!("truncated or corrupt: file is {file_len} B, header declares k={k} dim={dim}"),
        ));
    }
    r.read_exact(&mut b8).map_err(short)?;
    let seed = u64::from_le_bytes(b8);
    r.read_exact(&mut b4).map_err(short)?;
    let engine_len = u32::from_le_bytes(b4) as usize;
    if engine_len > 256 {
        return Err(data_err(path, format!("implausible engine-name length {engine_len}")));
    }
    let mut engine_buf = vec![0u8; engine_len];
    r.read_exact(&mut engine_buf).map_err(short)?;
    let engine = String::from_utf8(engine_buf)
        .map_err(|_| data_err(path, "engine name is not valid utf-8".into()))?;
    r.read_exact(&mut b8).map_err(short)?;
    let iterations = u64::from_le_bytes(b8) as usize;
    r.read_exact(&mut b8).map_err(short)?;
    let sse = f64::from_bits(u64::from_le_bytes(b8));

    let mut payload = vec![0u8; k * dim * 4];
    r.read_exact(&mut payload).map_err(|e| {
        data_err(path, format!("truncated centroids: header declares {k} × {dim}D ({e})"))
    })?;
    let centroids: Vec<f32> =
        payload.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    let mut extra = [0u8; 1];
    if r.read(&mut extra)? != 0 {
        return Err(data_err(path, "trailing bytes after the centroid payload".into()));
    }
    Ok(Model { k, dim, seed, engine, iterations, sse, centroids })
}

/// CSV header line for `dim` columns (`x0,x1,...`) — shared with the
/// CLI's streamed generator path so the two writers cannot drift.
pub fn csv_header(dim: usize) -> String {
    (0..dim).map(|j| format!("x{j}")).collect::<Vec<_>>().join(",")
}

/// One CSV data row (same formatting as [`write_csv`]).
pub fn csv_row(point: &[f32]) -> String {
    point.iter().map(|v| format!("{v}")).collect::<Vec<_>>().join(",")
}

/// Write CSV (no truth labels; header `x0,x1,...`).
pub fn write_csv(path: &Path, ds: &Dataset) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    writeln!(w, "{}", csv_header(ds.dim()))?;
    for i in 0..ds.len() {
        writeln!(w, "{}", csv_row(ds.point(i)))?;
    }
    Ok(())
}

/// Read CSV produced by [`write_csv`] (or any numeric CSV with header).
///
/// Rejects ragged rows (cell count ≠ header width) and non-numeric or
/// non-finite cells with [`Error::Data`] naming the offending row — a
/// dataset with silent `NaN` points would poison every distance.
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let (header, rows) = crate::util::csv::read_table(path)?;
    let dim = header.len();
    if dim == 0 {
        return Err(data_err(path, "csv has no columns".into()));
    }
    let mut data = Vec::with_capacity(rows.len() * dim);
    for (i, row) in rows.iter().enumerate() {
        if row.len() != dim {
            return Err(data_err(
                path,
                format!("csv row {i} has {} cells, expected {dim}", row.len()),
            ));
        }
        for (j, &v) in row.iter().enumerate() {
            // check after the f32 narrowing: a cell like 1e39 is
            // finite in f64 but saturates to inf as f32
            let f = v as f32;
            if !f.is_finite() {
                return Err(data_err(
                    path,
                    format!("csv row {i}, column {j}: non-numeric, non-finite or out-of-range"),
                ));
            }
            data.push(f);
        }
    }
    Dataset::from_vec(data, dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip_with_truth() {
        let ds = MixtureSpec::paper_2d(4).generate(257, 3);
        let p = tmp("rt.pkd");
        write_binary(&p, &ds).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(back.truth.is_some());
    }

    #[test]
    fn binary_roundtrip_without_truth() {
        let mut ds = MixtureSpec::paper_3d(4).generate(64, 3);
        ds.truth = None;
        let p = tmp("rt2.pkd");
        write_binary(&p, &ds).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(back.truth.is_none());
    }

    #[test]
    fn probe_reads_header_without_payload() {
        let ds = MixtureSpec::paper_3d(4).generate(1234, 7);
        let p = tmp("probe.pkd");
        write_binary(&p, &ds).unwrap();
        let h = probe_binary(&p).unwrap();
        assert_eq!(h.dim, 3);
        assert_eq!(h.n, 1234);
        assert!(h.has_truth);
        assert_eq!(h.payload_offset, BIN_HEADER_BYTES);
        assert_eq!(h.row_offset(10), BIN_HEADER_BYTES + 120);
        assert_eq!(h.truth_offset(), BIN_HEADER_BYTES + 1234 * 12);
    }

    #[test]
    fn rejects_bad_magic_typed() {
        let p = tmp("bad.pkd");
        std::fs::write(&p, b"NOTMAGIC123456789012345").unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_short_header_typed() {
        let p = tmp("short.pkd");
        std::fs::write(&p, b"PARA").unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
    }

    #[test]
    fn rejects_zero_dim_header() {
        let p = tmp("zdim.pkd");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&0u32.to_le_bytes());
        bytes.extend_from_slice(&5u64.to_le_bytes());
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
    }

    #[test]
    fn rejects_implausible_header() {
        let p = tmp("huge.pkd");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        bytes.extend_from_slice(&u64::MAX.to_le_bytes());
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("implausible"), "{err}");
    }

    #[test]
    fn rejects_lying_header_before_allocation() {
        // representable but false n: the declared payload must be on
        // disk, or probe fails typed instead of read_binary attempting
        // a header-sized allocation
        let p = tmp("liar.pkd");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&1u32.to_le_bytes());
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes());
        bytes.push(0);
        std::fs::write(&p, &bytes).unwrap();
        let err = probe_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated or corrupt"), "{err}");
    }

    fn sample_model() -> Model {
        Model {
            k: 3,
            dim: 2,
            seed: 42,
            engine: "dist".into(),
            iterations: 17,
            sse: 123.456789,
            // awkward bit patterns: -0.0, subnormal, almost-1
            centroids: vec![-0.0, f32::MIN_POSITIVE, 1.0000001, -5.25, 1e-30, 9.75],
        }
    }

    #[test]
    fn model_roundtrip_is_byte_exact_on_centroids() {
        let m = sample_model();
        let p = tmp("model_rt.pkm");
        write_model(&p, &m).unwrap();
        let back = read_model(&p).unwrap();
        assert_eq!(back.k, m.k);
        assert_eq!(back.dim, m.dim);
        assert_eq!(back.seed, m.seed);
        assert_eq!(back.engine, m.engine);
        assert_eq!(back.iterations, m.iterations);
        assert_eq!(back.sse.to_bits(), m.sse.to_bits());
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.centroids), bits(&m.centroids));
    }

    #[test]
    fn model_write_validates_shape() {
        let p = tmp("model_bad.pkm");
        let mut m = sample_model();
        m.centroids.pop();
        assert!(matches!(write_model(&p, &m).unwrap_err(), Error::Shape(_)));
        let mut m = sample_model();
        m.k = 0;
        m.centroids.clear();
        assert!(matches!(write_model(&p, &m).unwrap_err(), Error::Shape(_)));
    }

    #[test]
    fn model_corruption_is_typed() {
        let p = tmp("model_corrupt.pkm");
        write_model(&p, &sample_model()).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        std::fs::write(&p, &bad).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("bad magic"), "{err}");

        // truncated centroids
        std::fs::write(&p, &bytes[..bytes.len() - 5]).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");

        // lying header: a representable but false k×dim on a tiny file
        // must be a typed error BEFORE any allocation
        let mut lying = bytes.clone();
        lying[8..12].copy_from_slice(&u32::MAX.to_le_bytes()); // k
        lying[12..16].copy_from_slice(&(1u32 << 16).to_le_bytes()); // dim
        std::fs::write(&p, &lying).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated or corrupt"), "{err}");

        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        std::fs::write(&p, &long).unwrap();
        let err = read_model(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn csv_roundtrip() {
        let mut ds = MixtureSpec::paper_2d(4).generate(100, 9);
        ds.truth = None;
        let p = tmp("rt.csv");
        write_csv(&p, &ds).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.dim(), 2);
        assert_eq!(back.len(), 100);
        for i in 0..100 {
            for j in 0..2 {
                assert!((back.point(i)[j] - ds.point(i)[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn truncated_binary_errors_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(64, 3);
        let p = tmp("trunc.pkd");
        write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn truncated_truth_section_errors_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(64, 3);
        let p = tmp("trunc_truth.pkd");
        write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        // keep the payload intact, cut the truth labels short
        let keep = BIN_HEADER_BYTES as usize + 64 * 2 * 4 + 10;
        std::fs::write(&p, &bytes[..keep]).unwrap();
        let err = read_binary(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn ragged_csv_row_errors_typed() {
        let p = tmp("ragged.csv");
        std::fs::write(&p, "x0,x1\n1.0,2.0\n3.0\n").unwrap();
        let err = read_csv(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("row 1"), "{err}");
    }

    #[test]
    fn non_numeric_csv_cell_errors_typed() {
        let p = tmp("garbage.csv");
        std::fs::write(&p, "x0,x1\n1.0,2.0\n3.0,banana\n").unwrap();
        let err = read_csv(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("row 1"), "{err}");
    }

    #[test]
    fn f32_overflowing_csv_cell_errors_typed() {
        // finite in f64, +inf after the f32 narrowing — must not pass
        let p = tmp("overflow.csv");
        std::fs::write(&p, "x0,x1\n1.0,2.0\n3.0,1e39\n").unwrap();
        let err = read_csv(&p).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");
        assert!(err.to_string().contains("row 1, column 1"), "{err}");
    }

    #[test]
    fn bin_writer_streams_in_chunks() {
        let ds = MixtureSpec::paper_3d(4).generate(301, 5);
        let p = tmp("chunked.pkd");
        let mut w = BinWriter::create(&p, 3, 301, true).unwrap();
        // ragged chunking: 100 + 100 + 101 rows
        w.write_rows(ds.rows(0, 100)).unwrap();
        w.write_rows(ds.rows(100, 200)).unwrap();
        w.write_rows(ds.rows(200, 301)).unwrap();
        w.finish(ds.truth.as_deref()).unwrap();
        // byte-identical to the whole-dataset writer
        let p2 = tmp("whole.pkd");
        write_binary(&p2, &ds).unwrap();
        assert_eq!(std::fs::read(&p).unwrap(), std::fs::read(&p2).unwrap());
    }

    #[test]
    fn bin_writer_incremental_truth_matches_one_shot() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 7);
        let truth = ds.truth.clone().unwrap();
        let one_shot = tmp("truth_oneshot.pkd");
        write_binary(&one_shot, &ds).unwrap();

        let streamed = tmp("truth_streamed.pkd");
        let mut w = BinWriter::create(&streamed, 2, 100, true).unwrap();
        w.write_rows(ds.raw()).unwrap();
        w.write_truth(&truth[..40]).unwrap();
        w.write_truth(&truth[40..]).unwrap();
        w.finish(None).unwrap();
        assert_eq!(std::fs::read(&one_shot).unwrap(), std::fs::read(&streamed).unwrap());

        // truth before the payload completes is rejected
        let mut w = BinWriter::create(&tmp("early.pkd"), 2, 2, true).unwrap();
        assert!(w.write_truth(&[0]).is_err());
        // overrunning the label count is rejected
        let mut w = BinWriter::create(&tmp("over.pkd"), 2, 1, true).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        assert!(w.write_truth(&[0, 1]).is_err());
    }

    #[test]
    fn bin_writer_validates_counts() {
        let p = tmp("wv.pkd");
        let mut w = BinWriter::create(&p, 2, 3, false).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        assert!(w.write_rows(&[1.0, 2.0, 3.0]).is_err()); // ragged block
        assert!(w.write_rows(&[0.0; 8]).is_err()); // past declared n
        assert!(w.finish(None).is_err()); // short: 1 of 3 rows written

        let mut w = BinWriter::create(&p, 2, 1, false).unwrap();
        w.write_rows(&[1.0, 2.0]).unwrap();
        assert!(w.finish(Some(&[0])).is_err()); // unpromised truth
    }
}
