//! Dataset (de)serialization.
//!
//! Two formats:
//! - **binary** (`.pkd`): little-endian, magic + dim + n + f32 payload
//!   (+ optional truth labels). Fast path used by the CLI `gen-data` /
//!   `run` round trip for the 1M-point workloads.
//! - **CSV**: one point per row, interchange with external tools.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::data::Dataset;
use crate::error::{Error, Result};

const MAGIC: &[u8; 8] = b"PARAKMD1";

/// Write the binary format.
pub fn write_binary(path: &Path, ds: &Dataset) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    w.write_all(MAGIC)?;
    w.write_all(&(ds.dim() as u32).to_le_bytes())?;
    w.write_all(&(ds.len() as u64).to_le_bytes())?;
    let has_truth = ds.truth.is_some() as u8;
    w.write_all(&[has_truth])?;
    for v in ds.raw() {
        w.write_all(&v.to_le_bytes())?;
    }
    if let Some(truth) = &ds.truth {
        for t in truth {
            w.write_all(&t.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Read the binary format.
pub fn read_binary(path: &Path) -> Result<Dataset> {
    let mut r = BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::Manifest(format!(
            "{}: not a parakmeans dataset (bad magic)",
            path.display()
        )));
    }
    let mut b4 = [0u8; 4];
    r.read_exact(&mut b4)?;
    let dim = u32::from_le_bytes(b4) as usize;
    let mut b8 = [0u8; 8];
    r.read_exact(&mut b8)?;
    let n = u64::from_le_bytes(b8) as usize;
    let mut b1 = [0u8; 1];
    r.read_exact(&mut b1)?;
    let has_truth = b1[0] != 0;

    let mut payload = vec![0u8; n * dim * 4];
    r.read_exact(&mut payload)?;
    let mut data = Vec::with_capacity(n * dim);
    for c in payload.chunks_exact(4) {
        data.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let mut ds = Dataset::from_vec(data, dim)?;
    if has_truth {
        let mut tbuf = vec![0u8; n * 4];
        r.read_exact(&mut tbuf)?;
        let truth: Vec<i32> = tbuf
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect();
        ds.truth = Some(truth);
    }
    Ok(ds)
}

/// Write CSV (no truth labels; header `x0,x1,...`).
pub fn write_csv(path: &Path, ds: &Dataset) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut w = BufWriter::new(std::fs::File::create(path)?);
    let header: Vec<String> = (0..ds.dim()).map(|j| format!("x{j}")).collect();
    writeln!(w, "{}", header.join(","))?;
    for i in 0..ds.len() {
        let cells: Vec<String> = ds.point(i).iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", cells.join(","))?;
    }
    Ok(())
}

/// Read CSV produced by [`write_csv`] (or any numeric CSV with header).
pub fn read_csv(path: &Path) -> Result<Dataset> {
    let (header, rows) = crate::util::csv::read_table(path)?;
    let dim = header.len();
    if dim == 0 {
        return Err(Error::Shape("csv has no columns".into()));
    }
    let mut data = Vec::with_capacity(rows.len() * dim);
    for (i, row) in rows.iter().enumerate() {
        if row.len() != dim {
            return Err(Error::Shape(format!(
                "csv row {i} has {} cells, expected {dim}",
                row.len()
            )));
        }
        data.extend(row.iter().map(|&v| v as f32));
    }
    Dataset::from_vec(data, dim)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("parakm_io_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn binary_roundtrip_with_truth() {
        let ds = MixtureSpec::paper_2d(4).generate(257, 3);
        let p = tmp("rt.pkd");
        write_binary(&p, &ds).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(back.truth.is_some());
    }

    #[test]
    fn binary_roundtrip_without_truth() {
        let mut ds = MixtureSpec::paper_3d(4).generate(64, 3);
        ds.truth = None;
        let p = tmp("rt2.pkd");
        write_binary(&p, &ds).unwrap();
        let back = read_binary(&p).unwrap();
        assert_eq!(ds, back);
        assert!(back.truth.is_none());
    }

    #[test]
    fn rejects_bad_magic() {
        let p = tmp("bad.pkd");
        std::fs::write(&p, b"NOTMAGIC123456").unwrap();
        assert!(read_binary(&p).is_err());
    }

    #[test]
    fn csv_roundtrip() {
        let mut ds = MixtureSpec::paper_2d(4).generate(100, 9);
        ds.truth = None;
        let p = tmp("rt.csv");
        write_csv(&p, &ds).unwrap();
        let back = read_csv(&p).unwrap();
        assert_eq!(back.dim(), 2);
        assert_eq!(back.len(), 100);
        for i in 0..100 {
            for j in 0..2 {
                assert!((back.point(i)[j] - ds.point(i)[j]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn truncated_binary_errors() {
        let ds = MixtureSpec::paper_2d(4).generate(64, 3);
        let p = tmp("trunc.pkd");
        write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        assert!(read_binary(&p).is_err());
    }
}
