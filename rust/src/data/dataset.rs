//! In-memory dataset container.
//!
//! Row-major `f32` (`n × dim`, point `i` at `data[i*dim .. (i+1)*dim]`) —
//! the layout the AOT executables consume directly (no transpose or copy
//! on the request path) and the cache-friendly layout for the rust
//! assignment loop.

use std::sync::OnceLock;

use crate::error::{Error, Result};

/// A dense dataset of `n` points in `dim` dimensions.
#[derive(Debug, Clone)]
pub struct Dataset {
    dim: usize,
    data: Vec<f32>,
    /// Ground-truth component labels if synthetically generated
    /// (used by ARI/NMI validation, never by the clustering itself).
    pub truth: Option<Vec<i32>>,
    /// Lazily-computed per-row `‖x‖²` cache for the `dot` distance
    /// policy ([`Dataset::norms`]) — computed once per dataset, shared
    /// by every engine iteration. Invalidated by [`Dataset::push`].
    norms: OnceLock<Vec<f32>>,
}

/// Equality is over the data (dim, rows, truth) — whether the norm
/// cache has been materialized is not an observable property.
impl PartialEq for Dataset {
    fn eq(&self, other: &Dataset) -> bool {
        self.dim == other.dim && self.data == other.data && self.truth == other.truth
    }
}

impl Dataset {
    /// Wrap an existing row-major buffer.
    pub fn from_vec(data: Vec<f32>, dim: usize) -> Result<Dataset> {
        if dim == 0 {
            return Err(Error::Shape("dim must be > 0".into()));
        }
        if data.len() % dim != 0 {
            return Err(Error::Shape(format!(
                "buffer len {} not divisible by dim {dim}",
                data.len()
            )));
        }
        Ok(Dataset { dim, data, truth: None, norms: OnceLock::new() })
    }

    /// Empty dataset with reserved capacity.
    pub fn with_capacity(dim: usize, n: usize) -> Dataset {
        Dataset { dim, data: Vec::with_capacity(dim * n), truth: None, norms: OnceLock::new() }
    }

    #[inline(always)]
    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline(always)]
    pub fn len(&self) -> usize {
        self.data.len() / self.dim
    }

    #[inline(always)]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Point `i` as a slice.
    #[inline(always)]
    pub fn point(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Raw row-major buffer.
    #[inline(always)]
    pub fn raw(&self) -> &[f32] {
        &self.data
    }

    /// Rows `[lo, hi)` as a raw slice (shard view; zero-copy).
    #[inline(always)]
    pub fn rows(&self, lo: usize, hi: usize) -> &[f32] {
        &self.data[lo * self.dim..hi * self.dim]
    }

    pub fn push(&mut self, point: &[f32]) {
        assert_eq!(point.len(), self.dim);
        self.data.extend_from_slice(point);
        // the cached norms no longer cover every row
        let _ = self.norms.take();
    }

    /// Per-row squared norms `‖xᵢ‖²` — the `dot` distance policy's
    /// point-norm cache (DESIGN.md §11). Computed once on first use
    /// (one O(n·d) pass), then shared; engines running `exact` never
    /// pay for it.
    pub fn norms(&self) -> &[f32] {
        self.norms
            .get_or_init(|| crate::linalg::kernel::row_norms_vec(&self.data, self.dim))
    }

    /// Norms of rows `[lo, hi)` — the shard/chunk view matching
    /// [`Dataset::rows`].
    pub fn norms_range(&self, lo: usize, hi: usize) -> &[f32] {
        &self.norms()[lo..hi]
    }

    /// Split into `p` contiguous shards, sizes differing by at most 1
    /// (the paper's OpenMP data decomposition). Returns `(lo, hi)` row
    /// ranges covering `[0, n)` exactly.
    pub fn shard_ranges(&self, p: usize) -> Vec<(usize, usize)> {
        shard_ranges(self.len(), p)
    }

    /// Per-coordinate (min, max) bounding box — used by plot axes and
    /// test invariants.
    pub fn bounds(&self) -> Vec<(f32, f32)> {
        let mut b = vec![(f32::INFINITY, f32::NEG_INFINITY); self.dim];
        for i in 0..self.len() {
            let pt = self.point(i);
            for (j, &v) in pt.iter().enumerate() {
                b[j].0 = b[j].0.min(v);
                b[j].1 = b[j].1.max(v);
            }
        }
        b
    }

    /// Copy of column `j` (plotting).
    pub fn column(&self, j: usize) -> Vec<f32> {
        assert!(j < self.dim);
        (0..self.len()).map(|i| self.point(i)[j]).collect()
    }
}

/// Contiguous near-equal partition of `n` items into `p` shards.
pub fn shard_ranges(n: usize, p: usize) -> Vec<(usize, usize)> {
    assert!(p > 0, "shard_ranges: p == 0");
    let base = n / p;
    let extra = n % p;
    let mut out = Vec::with_capacity(p);
    let mut lo = 0;
    for i in 0..p {
        let size = base + usize::from(i < extra);
        out.push((lo, lo + size));
        lo += size;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_validates() {
        assert!(Dataset::from_vec(vec![1.0, 2.0, 3.0], 2).is_err());
        assert!(Dataset::from_vec(vec![], 0).is_err());
        let ds = Dataset::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2).unwrap();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.point(1), &[3.0, 4.0]);
    }

    #[test]
    fn push_and_views() {
        let mut ds = Dataset::with_capacity(3, 2);
        ds.push(&[1.0, 2.0, 3.0]);
        ds.push(&[4.0, 5.0, 6.0]);
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.rows(1, 2), &[4.0, 5.0, 6.0]);
        assert_eq!(ds.column(2), vec![3.0, 6.0]);
    }

    #[test]
    fn shard_ranges_cover_exactly() {
        for n in [0usize, 1, 7, 100, 101, 1_000_003] {
            for p in [1usize, 2, 3, 8, 16] {
                let r = shard_ranges(n, p);
                assert_eq!(r.len(), p);
                assert_eq!(r[0].0, 0);
                assert_eq!(r[p - 1].1, n);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0); // contiguous
                }
                let sizes: Vec<usize> = r.iter().map(|(a, b)| b - a).collect();
                let min = sizes.iter().min().unwrap();
                let max = sizes.iter().max().unwrap();
                assert!(max - min <= 1, "n={n} p={p} sizes={sizes:?}");
            }
        }
    }

    #[test]
    fn bounds() {
        let ds = Dataset::from_vec(vec![0.0, 5.0, -2.0, 3.0], 2).unwrap();
        assert_eq!(ds.bounds(), vec![(-2.0, 0.0), (3.0, 5.0)]);
    }

    #[test]
    fn norms_cached_and_invalidated_by_push() {
        let mut ds = Dataset::from_vec(vec![3.0, 4.0, 0.0, 2.0], 2).unwrap();
        assert_eq!(ds.norms(), &[25.0, 4.0]);
        // cached: same allocation on re-read
        let ptr = ds.norms().as_ptr();
        assert_eq!(ds.norms().as_ptr(), ptr);
        assert_eq!(ds.norms_range(1, 2), &[4.0]);
        // push invalidates the cache and the new row is covered
        ds.push(&[1.0, 1.0]);
        assert_eq!(ds.norms(), &[25.0, 4.0, 2.0]);
    }

    #[test]
    fn equality_ignores_norm_cache_state() {
        let a = Dataset::from_vec(vec![1.0, 2.0], 2).unwrap();
        let b = Dataset::from_vec(vec![1.0, 2.0], 2).unwrap();
        let _ = a.norms(); // materialize one side only
        assert_eq!(a, b);
    }
}
