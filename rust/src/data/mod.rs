//! Datasets: container, synthetic Gaussian-mixture generation (the
//! paper's 2D/3D dataset families), binary/CSV interchange, and the
//! out-of-core [`source::DataSource`] abstraction that lets engines
//! stream data larger than RAM (DESIGN.md §4).
//!
//! Layering: [`Dataset`] is the resident container every in-memory
//! engine consumes; [`source`] generalizes it to chunked streams
//! (memory, `.pkd` file, on-the-fly generator); [`io`] is the disk
//! format shared by the CLI, the eval harness and [`source::FileSource`];
//! [`gmm`] synthesizes the paper's dataset families.

pub mod dataset;
pub mod gmm;
pub mod io;
pub mod source;

pub use dataset::Dataset;
pub use gmm::MixtureSpec;
pub use source::{DataSource, FileSource, GmmSource, MemorySource, OwnedMemorySource};
