//! Datasets: container, synthetic Gaussian-mixture generation (the
//! paper's 2D/3D dataset families), and binary/CSV interchange.

pub mod dataset;
pub mod gmm;
pub mod io;

pub use dataset::Dataset;
pub use gmm::MixtureSpec;
