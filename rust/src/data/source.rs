//! Out-of-core data sources (DESIGN.md §4).
//!
//! The in-memory [`Dataset`] caps `n` at physical RAM — far below the
//! "big data" scale the paper's title claims. A [`DataSource`] removes
//! that cap: it describes `n × dim` rows that engines *stream* in
//! fixed-size chunks instead of holding resident, so a clustering
//! run's working set is O(chunk), not O(n). Three implementations:
//!
//! - [`MemorySource`] — wraps a [`Dataset`]; chunks are zero-copy
//!   subslices of the resident buffer (the degenerate case, used to
//!   run the streaming engine against in-memory references).
//! - [`FileSource`] — buffered streaming over the `.pkd` binary format
//!   ([`crate::data::io`]); each reader owns an independent file
//!   handle, so shard workers stream concurrently.
//! - [`GmmSource`] — synthesizes rows on the fly from a seeded
//!   [`MixtureSpec`]. Row `i` is derived from an `i`-indexed RNG
//!   stream, so any chunking (and any shard decomposition) yields
//!   bit-identical bytes — and `n` can exceed not just RAM but disk.
//!
//! ## The chunk contract
//!
//! A reader obtained from [`DataSource::reader`]`(lo, hi, chunk_rows)`
//! yields non-empty chunks that tile `[lo, hi)` contiguously in
//! ascending row order, each at most `chunk_rows` rows. Consumers rely
//! on this for the chunked-accumulation guarantee (see
//! [`crate::kmeans::streaming`]): folding chunks in arrival order is
//! bit-identical to processing the whole range at once. The engine
//! verifies the tiling at runtime and reports [`Error::Data`] on a
//! source that violates it.
//!
//! ```
//! use parakmeans::data::gmm::MixtureSpec;
//! use parakmeans::data::source::{ChunkReader, DataSource, GmmSource, MemorySource};
//!
//! // a generator-backed source: rows are synthesized on the fly
//! let src = GmmSource::new(MixtureSpec::paper_2d(4), 10_000, 7);
//! assert_eq!((src.len(), src.dim()), (10_000, 2));
//!
//! // stream rows [100, 300) in chunks of at most 128 rows
//! let mut reader = src.reader(100, 300, 128).unwrap();
//! let mut rows_seen = 0;
//! while let Some(chunk) = reader.next_chunk().unwrap() {
//!     rows_seen += chunk.rows.len() / src.dim();
//! }
//! assert_eq!(rows_seen, 200);
//!
//! // the same rows materialized: in-memory zero-copy access
//! let ds = src.materialize();
//! let mem = MemorySource::new(&ds);
//! assert_eq!(mem.len(), 10_000);
//! ```

use std::io::{BufReader, Read, Seek, SeekFrom};
use std::path::{Path, PathBuf};

use crate::data::gmm::MixtureSpec;
use crate::data::io::{self, BinHeader};
use crate::data::Dataset;
use crate::error::{Error, Result};
use crate::rng::Pcg64;

/// Rows per pass used by the default [`DataSource::gather`].
const GATHER_CHUNK_ROWS: usize = 8192;

/// One block of rows handed out by a [`ChunkReader`].
#[derive(Debug)]
pub struct Chunk<'a> {
    /// Global index of the first row in this chunk.
    pub lo: usize,
    /// Row-major data, `dim` wide (`rows.len() / dim` rows). Valid
    /// until the next [`ChunkReader::next_chunk`] call.
    pub rows: &'a [f32],
}

/// Sequential chunk iterator over a row range (see the module-level
/// chunk contract).
pub trait ChunkReader {
    /// The next chunk in ascending row order, or `None` once the range
    /// is exhausted. The returned slice borrows the reader's internal
    /// buffer and is valid until the next call.
    fn next_chunk(&mut self) -> Result<Option<Chunk<'_>>>;
}

/// A dataset that engines stream in fixed-size chunks instead of
/// holding resident (module docs: the chunk contract, implementations).
pub trait DataSource: Sync {
    /// Point dimensionality.
    fn dim(&self) -> usize;

    /// Total number of rows.
    fn len(&self) -> usize;

    /// `true` iff the source has no rows.
    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Open an independent reader over rows `[lo, hi)` yielding chunks
    /// of at most `chunk_rows` rows. Readers are independent: engines
    /// open one per shard worker and per pass, concurrently.
    fn reader(&self, lo: usize, hi: usize, chunk_rows: usize) -> Result<Box<dyn ChunkReader + '_>>;

    /// Whether [`DataSource::truth`] would return labels — an O(1)
    /// probe so callers can decide against the O(n) fetch.
    fn has_truth(&self) -> bool {
        false
    }

    /// Ground-truth component labels when the source carries them
    /// (synthetic data), `None` otherwise. O(n·4) bytes — the same
    /// order as the assignment vector every engine already returns,
    /// but check your memory budget before asking (see
    /// [`DataSource::has_truth`]).
    fn truth(&self) -> Result<Option<Vec<i32>>> {
        Ok(None)
    }

    /// One-line description for run reports.
    fn describe(&self) -> String;

    /// Fetch `indices` (any order, duplicates allowed) in one bounded-
    /// memory pass, returning the rows concatenated *in the order of
    /// `indices`* — what seeded random initialization needs.
    fn gather(&self, indices: &[usize]) -> Result<Vec<f32>> {
        let d = self.dim();
        let n = self.len();
        let mut order: Vec<(usize, usize)> =
            indices.iter().copied().enumerate().map(|(pos, idx)| (idx, pos)).collect();
        for &(idx, _) in &order {
            if idx >= n {
                return Err(Error::Config(format!("gather: row {idx} out of range (n = {n})")));
            }
        }
        order.sort_unstable();
        let mut out = vec![0.0f32; indices.len() * d];
        let mut pending = order.into_iter().peekable();
        let mut reader = self.reader(0, n, GATHER_CHUNK_ROWS)?;
        let mut next = 0usize;
        while let Some(chunk) = reader.next_chunk()? {
            // verify the tiling contract so a misbehaving reader is a
            // typed error, not an index underflow
            if chunk.lo != next || chunk.rows.is_empty() || chunk.rows.len() % d != 0 {
                return Err(Error::Data(format!(
                    "{}: reader broke the chunk contract at row {next} (chunk lo {}, len {})",
                    self.describe(),
                    chunk.lo,
                    chunk.rows.len()
                )));
            }
            let chunk_end = chunk.lo + chunk.rows.len() / d;
            next = chunk_end;
            while let Some(&(idx, pos)) = pending.peek() {
                if idx >= chunk_end {
                    break;
                }
                let r = idx - chunk.lo;
                out[pos * d..(pos + 1) * d].copy_from_slice(&chunk.rows[r * d..(r + 1) * d]);
                pending.next();
            }
            if pending.peek().is_none() {
                break;
            }
        }
        if pending.peek().is_some() {
            return Err(Error::Data(format!(
                "{}: reader ended before all gathered rows were seen",
                self.describe()
            )));
        }
        Ok(out)
    }
}

fn check_reader_args(lo: usize, hi: usize, n: usize, chunk_rows: usize) -> Result<()> {
    if chunk_rows == 0 {
        return Err(Error::Config("reader: chunk_rows must be >= 1".into()));
    }
    if lo > hi || hi > n {
        return Err(Error::Shape(format!("reader: range [{lo}, {hi}) out of bounds for n = {n}")));
    }
    Ok(())
}

// ---- in-memory (zero-copy) ---------------------------------------------

/// Zero-copy [`DataSource`] over a resident [`Dataset`]: chunks are
/// subslices of the dataset's own buffer.
pub struct MemorySource<'a> {
    ds: &'a Dataset,
}

impl<'a> MemorySource<'a> {
    pub fn new(ds: &'a Dataset) -> MemorySource<'a> {
        MemorySource { ds }
    }
}

struct MemReader<'a> {
    ds: &'a Dataset,
    cur: usize,
    hi: usize,
    chunk_rows: usize,
}

impl ChunkReader for MemReader<'_> {
    fn next_chunk(&mut self) -> Result<Option<Chunk<'_>>> {
        if self.cur >= self.hi {
            return Ok(None);
        }
        let hi = (self.cur + self.chunk_rows).min(self.hi);
        let chunk = Chunk { lo: self.cur, rows: self.ds.rows(self.cur, hi) };
        self.cur = hi;
        Ok(Some(chunk))
    }
}

impl DataSource for MemorySource<'_> {
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn len(&self) -> usize {
        self.ds.len()
    }

    fn reader(&self, lo: usize, hi: usize, chunk_rows: usize) -> Result<Box<dyn ChunkReader + '_>> {
        check_reader_args(lo, hi, self.len(), chunk_rows)?;
        Ok(Box::new(MemReader { ds: self.ds, cur: lo, hi, chunk_rows }))
    }

    fn has_truth(&self) -> bool {
        self.ds.truth.is_some()
    }

    fn truth(&self) -> Result<Option<Vec<i32>>> {
        Ok(self.ds.truth.clone())
    }

    fn describe(&self) -> String {
        format!("memory({} × {}D)", self.ds.len(), self.ds.dim())
    }
}

/// Owning variant of [`MemorySource`]: wraps the [`Dataset`] by value,
/// so the source is `'static` and can move across threads — what a
/// distributed shard worker or the loopback test harness needs
/// ([`crate::cluster`]). Chunks are zero-copy subslices of the owned
/// buffer, exactly as in [`MemorySource`].
pub struct OwnedMemorySource {
    ds: Dataset,
}

impl OwnedMemorySource {
    pub fn new(ds: Dataset) -> OwnedMemorySource {
        OwnedMemorySource { ds }
    }

    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }
}

impl DataSource for OwnedMemorySource {
    fn dim(&self) -> usize {
        self.ds.dim()
    }

    fn len(&self) -> usize {
        self.ds.len()
    }

    fn reader(&self, lo: usize, hi: usize, chunk_rows: usize) -> Result<Box<dyn ChunkReader + '_>> {
        check_reader_args(lo, hi, self.len(), chunk_rows)?;
        Ok(Box::new(MemReader { ds: &self.ds, cur: lo, hi, chunk_rows }))
    }

    fn has_truth(&self) -> bool {
        self.ds.truth.is_some()
    }

    fn truth(&self) -> Result<Option<Vec<i32>>> {
        Ok(self.ds.truth.clone())
    }

    fn describe(&self) -> String {
        format!("memory-owned({} × {}D)", self.ds.len(), self.ds.dim())
    }
}

// ---- file-backed (.pkd streaming) --------------------------------------

/// Buffered streaming [`DataSource`] over a `.pkd` binary file
/// ([`crate::data::io`] format). Holds only the parsed header; every
/// reader opens its own handle, so shards stream concurrently and a
/// run's resident set is O(shards × chunk × dim).
pub struct FileSource {
    path: PathBuf,
    header: BinHeader,
}

impl FileSource {
    /// Probe `path`'s header ([`io::probe_binary`]) without reading the
    /// payload.
    pub fn open(path: &Path) -> Result<FileSource> {
        let header = io::probe_binary(path)?;
        Ok(FileSource { path: path.to_path_buf(), header })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

struct FileReader {
    path: PathBuf,
    r: BufReader<std::fs::File>,
    dim: usize,
    cur: usize,
    hi: usize,
    chunk_rows: usize,
    byte_buf: Vec<u8>,
    row_buf: Vec<f32>,
}

impl ChunkReader for FileReader {
    fn next_chunk(&mut self) -> Result<Option<Chunk<'_>>> {
        if self.cur >= self.hi {
            return Ok(None);
        }
        let nrows = (self.hi - self.cur).min(self.chunk_rows);
        self.byte_buf.resize(nrows * self.dim * 4, 0);
        self.r.read_exact(&mut self.byte_buf).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Error::Data(format!(
                    "{}: truncated payload at row {} (header promises more)",
                    self.path.display(),
                    self.cur
                ))
            } else {
                Error::Io(e)
            }
        })?;
        self.row_buf.clear();
        self.row_buf.extend(
            self.byte_buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
        );
        let lo = self.cur;
        self.cur += nrows;
        Ok(Some(Chunk { lo, rows: &self.row_buf }))
    }
}

impl DataSource for FileSource {
    fn dim(&self) -> usize {
        self.header.dim
    }

    fn len(&self) -> usize {
        self.header.n
    }

    fn reader(&self, lo: usize, hi: usize, chunk_rows: usize) -> Result<Box<dyn ChunkReader + '_>> {
        check_reader_args(lo, hi, self.len(), chunk_rows)?;
        let f = std::fs::File::open(&self.path)?;
        // IO buffer at most one chunk payload (capped at 1 MiB) so a
        // small --memory-budget is never exceeded by buffering — the
        // ×3 overhead (IO buffer + raw bytes + decoded rows) is
        // exactly what StreamOpts::resolve budgets for
        let cap = (chunk_rows * self.header.dim * 4).min(1 << 20);
        let mut r = BufReader::with_capacity(cap, f);
        r.seek(SeekFrom::Start(self.header.row_offset(lo)))?;
        Ok(Box::new(FileReader {
            path: self.path.clone(),
            r,
            dim: self.header.dim,
            cur: lo,
            hi,
            chunk_rows,
            byte_buf: Vec::new(),
            row_buf: Vec::new(),
        }))
    }

    fn has_truth(&self) -> bool {
        self.header.has_truth
    }

    fn truth(&self) -> Result<Option<Vec<i32>>> {
        if !self.header.has_truth {
            return Ok(None);
        }
        let mut f = std::fs::File::open(&self.path)?;
        f.seek(SeekFrom::Start(self.header.truth_offset()))?;
        let mut buf = vec![0u8; self.header.n * 4];
        f.read_exact(&mut buf).map_err(|e| {
            if e.kind() == std::io::ErrorKind::UnexpectedEof {
                Error::Data(format!("{}: truncated truth section", self.path.display()))
            } else {
                Error::Io(e)
            }
        })?;
        Ok(Some(
            buf.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect(),
        ))
    }

    fn describe(&self) -> String {
        format!("file({}, {} × {}D)", self.path.display(), self.header.n, self.header.dim)
    }

    /// O(k) seeks instead of the default full-stream pass.
    fn gather(&self, indices: &[usize]) -> Result<Vec<f32>> {
        let d = self.header.dim;
        let mut out = vec![0.0f32; indices.len() * d];
        let mut f = std::fs::File::open(&self.path)?;
        let mut buf = vec![0u8; d * 4];
        for (pos, &idx) in indices.iter().enumerate() {
            if idx >= self.header.n {
                return Err(Error::Config(format!(
                    "gather: row {idx} out of range (n = {})",
                    self.header.n
                )));
            }
            f.seek(SeekFrom::Start(self.header.row_offset(idx)))?;
            f.read_exact(&mut buf).map_err(|e| {
                if e.kind() == std::io::ErrorKind::UnexpectedEof {
                    Error::Data(format!("{}: truncated payload at row {idx}", self.path.display()))
                } else {
                    Error::Io(e)
                }
            })?;
            for (j, c) in buf.chunks_exact(4).enumerate() {
                out[pos * d + j] = f32::from_le_bytes([c[0], c[1], c[2], c[3]]);
            }
        }
        Ok(out)
    }
}

// ---- generator-backed (unbounded n) ------------------------------------

/// On-the-fly seeded GMM [`DataSource`]: row `i` is a pure function of
/// `(spec, seed, i)` via an `i`-indexed RNG stream, so any chunk size
/// and any shard decomposition observe bit-identical bytes — and `n`
/// is bounded by neither RAM nor disk.
///
/// Note this *streamed* family draws a different (equally distributed)
/// sample sequence than [`MixtureSpec::generate`], whose single
/// sequential RNG cannot be entered mid-stream in O(1). The two
/// families share specs, and [`GmmSource::materialize`] produces the
/// streamed family's exact rows in memory for cross-checking.
pub struct GmmSource {
    spec: MixtureSpec,
    n: usize,
    seed: u64,
    /// Unnormalized component weights, precomputed from the spec.
    weights: Vec<f64>,
}

impl GmmSource {
    pub fn new(spec: MixtureSpec, n: usize, seed: u64) -> GmmSource {
        let weights = spec.components.iter().map(|c| c.weight).collect();
        GmmSource { spec, n, seed, weights }
    }

    /// Paper-family source: the 2D/3D specs of
    /// [`MixtureSpec::paper_2d`]/[`MixtureSpec::paper_3d`] with their
    /// generator component counts.
    pub fn paper(dim: usize, n: usize, seed: u64) -> Result<GmmSource> {
        use crate::data::gmm::workloads;
        let spec = match dim {
            2 => MixtureSpec::paper_2d(workloads::GEN_K_2D),
            3 => MixtureSpec::paper_3d(workloads::GEN_K_3D),
            d => return Err(Error::Config(format!("paper GMM families are 2D/3D, got {d}D"))),
        };
        Ok(GmmSource::new(spec, n, seed))
    }

    fn row_rng(&self, i: usize) -> Pcg64 {
        Pcg64::new(
            self.seed.wrapping_add((i as u64).wrapping_mul(0x9E3779B97F4A7C15)),
            0x6A11 ^ i as u64,
        )
    }

    /// Ground-truth component of row `i` (the row's first RNG draw, so
    /// no coordinates are synthesized).
    pub fn label_of(&self, i: usize) -> i32 {
        self.row_rng(i).next_weighted(&self.weights) as i32
    }

    /// Append rows `[lo, hi)` (and their labels, if asked) to `out`.
    pub fn generate_into(
        &self,
        lo: usize,
        hi: usize,
        out: &mut Vec<f32>,
        mut labels: Option<&mut Vec<i32>>,
    ) {
        let d = self.spec.dim;
        let mut scratch = crate::data::gmm::SampleScratch::new(d);
        let mut pt = vec![0.0f32; d];
        for i in lo..hi {
            let mut rng = self.row_rng(i);
            let ci = self.spec.sample_row(&mut rng, &self.weights, &mut scratch, &mut pt);
            out.extend_from_slice(&pt);
            if let Some(lbls) = labels.as_mut() {
                lbls.push(ci as i32);
            }
        }
    }

    /// Generate all rows into a resident [`Dataset`] (with truth
    /// labels) — for tests and cross-checks against in-memory engines.
    pub fn materialize(&self) -> Dataset {
        let mut data = Vec::with_capacity(self.n * self.spec.dim);
        let mut labels = Vec::with_capacity(self.n);
        self.generate_into(0, self.n, &mut data, Some(&mut labels));
        let mut ds =
            Dataset::from_vec(data, self.spec.dim).expect("generator rows are rectangular");
        ds.truth = Some(labels);
        ds
    }
}

struct GmmReader<'a> {
    src: &'a GmmSource,
    cur: usize,
    hi: usize,
    chunk_rows: usize,
    buf: Vec<f32>,
}

impl ChunkReader for GmmReader<'_> {
    fn next_chunk(&mut self) -> Result<Option<Chunk<'_>>> {
        if self.cur >= self.hi {
            return Ok(None);
        }
        let hi = (self.cur + self.chunk_rows).min(self.hi);
        self.buf.clear();
        self.src.generate_into(self.cur, hi, &mut self.buf, None);
        let lo = self.cur;
        self.cur = hi;
        Ok(Some(Chunk { lo, rows: &self.buf }))
    }
}

impl DataSource for GmmSource {
    fn dim(&self) -> usize {
        self.spec.dim
    }

    fn len(&self) -> usize {
        self.n
    }

    fn reader(&self, lo: usize, hi: usize, chunk_rows: usize) -> Result<Box<dyn ChunkReader + '_>> {
        check_reader_args(lo, hi, self.n, chunk_rows)?;
        Ok(Box::new(GmmReader { src: self, cur: lo, hi, chunk_rows, buf: Vec::new() }))
    }

    fn has_truth(&self) -> bool {
        true
    }

    fn truth(&self) -> Result<Option<Vec<i32>>> {
        Ok(Some((0..self.n).map(|i| self.label_of(i)).collect()))
    }

    fn describe(&self) -> String {
        format!(
            "gmm({}D × {} components, n = {}, seed = {})",
            self.spec.dim,
            self.spec.components.len(),
            self.n,
            self.seed
        )
    }

    /// Row `i` is an O(1) function of `i` — synthesize exactly the
    /// requested rows instead of the default full-stream pass.
    fn gather(&self, indices: &[usize]) -> Result<Vec<f32>> {
        let mut out = Vec::with_capacity(indices.len() * self.spec.dim);
        for &i in indices {
            if i >= self.n {
                return Err(Error::Config(format!(
                    "gather: row {i} out of range (n = {})",
                    self.n
                )));
            }
            self.generate_into(i, i + 1, &mut out, None);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("parakm_source_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    /// Drain a reader, checking the tiling contract, returning all rows.
    fn drain(src: &dyn DataSource, lo: usize, hi: usize, chunk: usize) -> Vec<f32> {
        let d = src.dim();
        let mut reader = src.reader(lo, hi, chunk).unwrap();
        let mut all = Vec::new();
        let mut next = lo;
        while let Some(c) = reader.next_chunk().unwrap() {
            assert_eq!(c.lo, next, "chunks not contiguous");
            let nrows = c.rows.len() / d;
            assert!(nrows >= 1 && nrows <= chunk, "chunk size {nrows} out of [1, {chunk}]");
            all.extend_from_slice(c.rows);
            next += nrows;
        }
        assert_eq!(next, hi, "reader did not cover the range");
        all
    }

    #[test]
    fn memory_source_is_zero_copy_view() {
        let ds = MixtureSpec::paper_2d(4).generate(503, 1);
        let src = MemorySource::new(&ds);
        assert_eq!(src.len(), 503);
        assert_eq!(src.dim(), 2);
        for chunk in [1usize, 64, 100, 503, 10_000] {
            assert_eq!(drain(&src, 0, 503, chunk), ds.raw());
        }
        // sub-range
        assert_eq!(drain(&src, 17, 200, 50), ds.rows(17, 200));
        assert_eq!(src.truth().unwrap(), ds.truth);
    }

    #[test]
    fn owned_memory_source_matches_borrowed() {
        let ds = MixtureSpec::paper_2d(4).generate(211, 4);
        let owned = OwnedMemorySource::new(ds.clone());
        let borrowed = MemorySource::new(&ds);
        assert_eq!((owned.len(), owned.dim()), (borrowed.len(), borrowed.dim()));
        assert_eq!(drain(&owned, 0, 211, 64), drain(&borrowed, 0, 211, 64));
        assert_eq!(owned.truth().unwrap(), ds.truth);
        assert!(owned.has_truth());
        assert_eq!(owned.gather(&[5, 0, 210]).unwrap(), borrowed.gather(&[5, 0, 210]).unwrap());
        assert_eq!(owned.dataset().len(), 211);
    }

    #[test]
    fn file_source_streams_exact_bytes() {
        let ds = MixtureSpec::paper_3d(4).generate(777, 5);
        let p = tmp("stream.pkd");
        io::write_binary(&p, &ds).unwrap();
        let src = FileSource::open(&p).unwrap();
        assert_eq!((src.len(), src.dim()), (777, 3));
        for chunk in [1usize, 100, 777, 4096] {
            assert_eq!(drain(&src, 0, 777, chunk), ds.raw());
        }
        assert_eq!(drain(&src, 300, 500, 64), ds.rows(300, 500));
        assert_eq!(src.truth().unwrap(), ds.truth);
    }

    #[test]
    fn file_source_truncation_is_typed_error() {
        let ds = MixtureSpec::paper_3d(4).generate(500, 5);
        let p = tmp("trunc.pkd");
        io::write_binary(&p, &ds).unwrap();
        let bytes = std::fs::read(&p).unwrap();

        // a file already truncated at open is rejected by the probe
        let cut = tmp("trunc_at_open.pkd");
        std::fs::write(&cut, &bytes[..bytes.len() / 2]).unwrap();
        let err = FileSource::open(&cut).unwrap_err();
        assert!(matches!(err, Error::Data(_)), "{err}");

        // a file that shrinks AFTER open (external race) errors at the
        // reader, typed, instead of hanging or panicking
        let src = FileSource::open(&p).unwrap();
        std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
        let mut r = src.reader(0, 500, 200).unwrap();
        let mut err = None;
        for _ in 0..3 {
            match r.next_chunk() {
                Ok(Some(_)) => continue,
                Ok(None) => break,
                Err(e) => {
                    err = Some(e);
                    break;
                }
            }
        }
        let err = err.expect("truncated stream must error");
        assert!(matches!(err, Error::Data(_)), "{err}");
    }

    #[test]
    fn gmm_source_chunking_is_bit_invariant() {
        let src = GmmSource::new(MixtureSpec::paper_2d(4), 1001, 42);
        let whole = drain(&src, 0, 1001, 1001);
        for chunk in [1usize, 37, 256, 1000] {
            assert_eq!(drain(&src, 0, 1001, chunk), whole);
        }
        // shard decomposition is also invariant
        let mut sharded = drain(&src, 0, 400, 128);
        sharded.extend(drain(&src, 400, 1001, 128));
        assert_eq!(sharded, whole);
        // materialize matches the streamed bytes and labels
        let ds = src.materialize();
        assert_eq!(ds.raw(), &whole[..]);
        assert_eq!(src.truth().unwrap(), ds.truth);
    }

    #[test]
    fn gmm_source_recovers_component_structure() {
        // one far-apart spec: labels must correspond to nearest means
        let spec = MixtureSpec::random(2, 4, 100.0, 0.1, 3);
        let src = GmmSource::new(spec, 2000, 9);
        let ds = src.materialize();
        let truth = ds.truth.as_ref().unwrap();
        let mut seen = [false; 4];
        for i in 0..ds.len() {
            seen[truth[i] as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "some component emitted no rows");
    }

    #[test]
    fn gather_preserves_index_order() {
        let ds = MixtureSpec::paper_2d(4).generate(300, 2);
        let src = MemorySource::new(&ds);
        let idx = [250usize, 3, 3, 299, 0];
        let rows = src.gather(&idx).unwrap();
        assert_eq!(rows.len(), idx.len() * 2);
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(&rows[pos * 2..(pos + 1) * 2], ds.point(i), "pos {pos}");
        }
        // same through the file-backed seek override
        let p = tmp("gather.pkd");
        io::write_binary(&p, &ds).unwrap();
        let fsrc = FileSource::open(&p).unwrap();
        assert_eq!(fsrc.gather(&idx).unwrap(), rows);

        // the generator's O(1)-per-row override matches its own
        // materialized rows
        let gmm = GmmSource::new(MixtureSpec::paper_2d(4), 300, 2);
        let gds = gmm.materialize();
        let grows = gmm.gather(&idx).unwrap();
        for (pos, &i) in idx.iter().enumerate() {
            assert_eq!(&grows[pos * 2..(pos + 1) * 2], gds.point(i), "gmm pos {pos}");
        }
    }

    #[test]
    fn gather_rejects_out_of_range() {
        let ds = MixtureSpec::paper_2d(4).generate(10, 2);
        let src = MemorySource::new(&ds);
        assert!(matches!(src.gather(&[5, 10]).unwrap_err(), Error::Config(_)));
        let p = tmp("gather_oor.pkd");
        io::write_binary(&p, &ds).unwrap();
        let fsrc = FileSource::open(&p).unwrap();
        assert!(matches!(fsrc.gather(&[10]).unwrap_err(), Error::Config(_)));
        let gmm = GmmSource::new(MixtureSpec::paper_2d(4), 10, 2);
        assert!(matches!(gmm.gather(&[10]).unwrap_err(), Error::Config(_)));
    }

    #[test]
    fn reader_arg_validation() {
        let ds = MixtureSpec::paper_2d(4).generate(10, 2);
        let src = MemorySource::new(&ds);
        assert!(src.reader(0, 10, 0).is_err()); // zero chunk
        assert!(src.reader(5, 3, 4).is_err()); // inverted range
        assert!(src.reader(0, 11, 4).is_err()); // past n
        assert!(src.reader(10, 10, 4).unwrap().next_chunk().unwrap().is_none()); // empty ok
    }

    #[test]
    fn paper_source_matches_eval_families() {
        let s2 = GmmSource::paper(2, 100, 1).unwrap();
        assert_eq!(s2.dim(), 2);
        let s3 = GmmSource::paper(3, 100, 1).unwrap();
        assert_eq!(s3.dim(), 3);
        assert!(GmmSource::paper(5, 100, 1).is_err());
    }
}
