//! Gaussian-mixture dataset generator — the paper's dataset families.
//!
//! The paper: *"all three of them are generated in a similar manner
//! using a mixture of Bivariate Gaussian Distributions of some mean and
//! covariance"*, 2D sizes {100k, 200k, 500k} and 3D sizes
//! {100k, 200k, 400k, 800k, 1M}. Exact parameters are unspecified
//! (DESIGN.md §8), so [`MixtureSpec::paper_2d`]/[`MixtureSpec::paper_3d`] fix a
//! deterministic family: component means on a jittered grid scaled to
//! keep components distinguishable-but-overlapping (like the paper's
//! Figure 5 clustering), random SPD covariances via Cholesky, equal
//! weights with a seeded tilt. Everything reproduces bit-for-bit from
//! `(spec, n, seed)`.

use crate::data::Dataset;
use crate::linalg;
use crate::rng::Pcg64;

/// One mixture component.
#[derive(Debug, Clone)]
pub struct Component {
    pub mean: Vec<f64>,
    /// Lower-triangular Cholesky factor of the covariance (row-major d×d).
    pub chol: Vec<f64>,
    /// Unnormalized weight.
    pub weight: f64,
}

/// A mixture-of-Gaussians generator specification.
#[derive(Debug, Clone)]
pub struct MixtureSpec {
    pub dim: usize,
    pub components: Vec<Component>,
}

impl MixtureSpec {
    /// Random-but-seeded spec: `k` components in `dim` dims, means on a
    /// jittered grid of pitch `spread`, covariances `scale² · (I + ε)`.
    pub fn random(dim: usize, k: usize, spread: f64, scale: f64, seed: u64) -> MixtureSpec {
        assert!(dim >= 1 && k >= 1);
        let mut rng = Pcg64::new(seed, 0xC0);
        // grid side: ceil(k^(1/dim))
        let side = (k as f64).powf(1.0 / dim as f64).ceil() as usize;
        let mut components = Vec::with_capacity(k);
        for c in 0..k {
            // grid coordinates of component c
            let mut rem = c;
            let mut mean = Vec::with_capacity(dim);
            for _ in 0..dim {
                let g = rem % side;
                rem /= side;
                let jitter = (rng.next_f64() - 0.5) * 0.35 * spread;
                mean.push(g as f64 * spread + jitter);
            }
            // random SPD covariance: A = scale^2 * (I + 0.5 B B^T), B small
            let mut b = vec![0.0f64; dim * dim];
            for v in b.iter_mut() {
                *v = (rng.next_f64() - 0.5) * 0.8;
            }
            let mut a = vec![0.0f64; dim * dim];
            for i in 0..dim {
                for j in 0..dim {
                    let mut acc = if i == j { 1.0 } else { 0.0 };
                    for l in 0..dim {
                        acc += 0.5 * b[i * dim + l] * b[j * dim + l];
                    }
                    a[i * dim + j] = acc * scale * scale;
                }
            }
            let chol = linalg::cholesky(&a, dim).expect("constructed SPD");
            let weight = 0.5 + rng.next_f64(); // mild imbalance
            components.push(Component { mean, chol, weight });
        }
        MixtureSpec { dim, components }
    }

    /// The paper's 2D family (Tables 2/4, Figures 5/6): `k` bivariate
    /// Gaussians with overlapping regions ("closely spaced groups of
    /// points" — the paper's own description of Figure 5).
    pub fn paper_2d(k: usize) -> MixtureSpec {
        MixtureSpec::random(2, k, 10.0, 1.6, 0x2D2D)
    }

    /// The paper's 3D family (Tables 3/5, Figures 1-4): well-separated
    /// enough that K=4 clustering is "optimal" per the paper's Figure 1.
    pub fn paper_3d(k: usize) -> MixtureSpec {
        MixtureSpec::random(3, k, 14.0, 1.2, 0x3D3D)
    }

    pub fn k(&self) -> usize {
        self.components.len()
    }

    /// Stateful sequential row sampler seeded by `seed` — the
    /// incremental form of [`MixtureSpec::generate`]. Drawing `n` rows
    /// through a sampler yields exactly the bytes `generate(n, seed)`
    /// would (the CLI's `gen-data --chunk` streaming path relies on
    /// this to write files larger than RAM without changing content).
    pub fn sampler(&self, seed: u64) -> MixtureSampler<'_> {
        MixtureSampler {
            spec: self,
            rng: Pcg64::new(seed, 0xDA7A),
            weights: self.components.iter().map(|c| c.weight).collect(),
            scratch: SampleScratch::new(self.dim),
        }
    }

    /// Generate `n` points. Component choice and noise are both driven
    /// by `seed`; ground-truth labels are stored on the dataset.
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let mut sampler = self.sampler(seed);
        let mut ds = Dataset::with_capacity(self.dim, n);
        let mut truth = Vec::with_capacity(n);
        let mut pt = vec![0.0f32; self.dim];
        for _ in 0..n {
            truth.push(sampler.next_row(&mut pt) as i32);
            ds.push(&pt);
        }
        ds.truth = Some(truth);
        ds
    }
}

/// Sequential mixture sampler (see [`MixtureSpec::sampler`]). One RNG
/// stream drives all rows, so rows must be drawn in order — for O(1)
/// random access use [`crate::data::source::GmmSource`] instead.
pub struct MixtureSampler<'a> {
    spec: &'a MixtureSpec,
    rng: Pcg64,
    weights: Vec<f64>,
    scratch: SampleScratch,
}

impl MixtureSampler<'_> {
    /// Draw the next row into `pt` (`pt.len() == dim`), returning the
    /// ground-truth component index.
    pub fn next_row(&mut self, pt: &mut [f32]) -> usize {
        self.spec.sample_row(&mut self.rng, &self.weights, &mut self.scratch, pt)
    }
}

/// Caller-owned scratch for [`MixtureSpec::sample_row`] (`z` normals,
/// `noise` = chol·z), so per-row sampling allocates nothing.
pub(crate) struct SampleScratch {
    z: Vec<f64>,
    noise: Vec<f64>,
}

impl SampleScratch {
    pub(crate) fn new(dim: usize) -> SampleScratch {
        SampleScratch { z: vec![0.0f64; dim], noise: vec![0.0f64; dim] }
    }
}

impl MixtureSpec {
    /// The one row-sampling kernel both generator families share
    /// (sequential [`MixtureSampler`] and the per-row-seeded
    /// [`crate::data::source::GmmSource`]): weighted component pick,
    /// `dim` standard normals through the component's Cholesky factor,
    /// mean + noise narrowed to f32. `weights` and `scratch` are
    /// caller-owned so the per-row hot loop allocates nothing.
    pub(crate) fn sample_row(
        &self,
        rng: &mut Pcg64,
        weights: &[f64],
        scratch: &mut SampleScratch,
        pt: &mut [f32],
    ) -> usize {
        debug_assert_eq!(pt.len(), self.dim);
        let ci = rng.next_weighted(weights);
        let comp = &self.components[ci];
        for v in scratch.z.iter_mut() {
            *v = rng.next_normal();
        }
        linalg::tril_matvec_into(&comp.chol, &scratch.z, self.dim, &mut scratch.noise);
        for j in 0..self.dim {
            pt[j] = (comp.mean[j] + scratch.noise[j]) as f32;
        }
        ci
    }
}

/// The paper's named workloads, used throughout eval/benches.
pub mod workloads {
    /// 2D dataset sizes (Tables 2/4, Figures 8/10/12).
    pub const SIZES_2D: [usize; 3] = [100_000, 200_000, 500_000];
    /// 3D dataset sizes (Tables 3/5, Figures 7/9/11).
    pub const SIZES_3D: [usize; 5] = [100_000, 200_000, 400_000, 800_000, 1_000_000];
    /// Thread counts swept in Tables 2/3 and Figures 7-10.
    pub const THREADS: [usize; 4] = [2, 4, 8, 16];
    /// Cluster counts in Table 1.
    pub const TABLE1_KS: [usize; 3] = [4, 8, 11];
    /// K fixed for the 2D parallel experiments.
    pub const K_2D: usize = 8;
    /// K fixed for the 3D parallel experiments.
    pub const K_3D: usize = 4;
    /// True component count used when *generating* the paper datasets.
    /// The paper clusters the same data with several K values; we fix
    /// the generator at 8 components (2D) / 4 (3D) to match the plotted
    /// structure in Figures 1-6.
    pub const GEN_K_2D: usize = 8;
    pub const GEN_K_3D: usize = 4;
    /// Deterministic per-size seed so every bench sees identical data.
    pub fn seed_for(dim: usize, n: usize) -> u64 {
        0x5EED_0000 ^ ((dim as u64) << 32) ^ n as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sampler_matches_generate_bitwise() {
        let spec = MixtureSpec::paper_3d(4);
        let ds = spec.generate(501, 9);
        let mut sampler = spec.sampler(9);
        let mut pt = vec![0.0f32; 3];
        for i in 0..501 {
            let ci = sampler.next_row(&mut pt);
            assert_eq!(&pt[..], ds.point(i), "row {i}");
            assert_eq!(ci as i32, ds.truth.as_ref().unwrap()[i], "label {i}");
        }
    }

    #[test]
    fn deterministic() {
        let spec = MixtureSpec::paper_2d(4);
        let a = spec.generate(1000, 7);
        let b = spec.generate(1000, 7);
        assert_eq!(a, b);
        let c = spec.generate(1000, 8);
        assert_ne!(a, c);
    }

    #[test]
    fn shapes_and_truth() {
        let spec = MixtureSpec::paper_3d(4);
        let ds = spec.generate(500, 1);
        assert_eq!(ds.dim(), 3);
        assert_eq!(ds.len(), 500);
        let truth = ds.truth.as_ref().unwrap();
        assert_eq!(truth.len(), 500);
        assert!(truth.iter().all(|&t| (0..4).contains(&t)));
        // all components actually emit points
        let mut seen = [false; 4];
        for &t in truth {
            seen[t as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn component_stats_match_spec() {
        // one isolated component: sample mean ~ spec mean
        let spec = MixtureSpec::random(2, 1, 10.0, 1.0, 3);
        let ds = spec.generate(20_000, 5);
        let m = &spec.components[0].mean;
        let mut sum = [0.0f64; 2];
        for i in 0..ds.len() {
            let p = ds.point(i);
            sum[0] += p[0] as f64;
            sum[1] += p[1] as f64;
        }
        let n = ds.len() as f64;
        assert!((sum[0] / n - m[0]).abs() < 0.05, "{} vs {}", sum[0] / n, m[0]);
        assert!((sum[1] / n - m[1]).abs() < 0.05);
    }

    #[test]
    fn components_are_separated() {
        // paper_3d means must be pairwise farther apart than ~4 sigma so
        // K=4 clustering is recoverable (paper Figure 1 "optimal")
        let spec = MixtureSpec::paper_3d(4);
        for i in 0..4 {
            for j in (i + 1)..4 {
                let a = &spec.components[i].mean;
                let b = &spec.components[j].mean;
                let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
                assert!(d2.sqrt() > 6.0, "components {i},{j} too close: {}", d2.sqrt());
            }
        }
    }

    #[test]
    fn workload_seed_unique() {
        use workloads::seed_for;
        let mut seen = std::collections::HashSet::new();
        for n in workloads::SIZES_3D {
            assert!(seen.insert(seed_for(3, n)));
        }
        for n in workloads::SIZES_2D {
            assert!(seen.insert(seed_for(2, n)));
        }
    }
}
