//! Run configuration shared by the CLI, engines, eval harness and
//! examples.

use crate::error::{Error, Result};
use crate::linalg::kernel::KernelChoice;

pub use crate::linalg::kernel::DistancePolicy;

/// Which engine executes the Lloyd iterations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Engine {
    /// Pure-rust serial Lloyd (paper's serial C program).
    Serial,
    /// Pure-rust shared-memory threads (paper's OpenMP program).
    Threads,
    /// AOT shared-memory leader/worker engine (OpenMP model over the
    /// PJRT executables).
    Shared,
    /// AOT device-offload engine (OpenACC model).
    Offload,
    /// Triangle-inequality accelerated serial baselines (paper ref [4]).
    Elkan,
    Hamerly,
    /// Mini-batch extension.
    MiniBatch,
    /// Out-of-core streaming over the AOT runtime (reads a .pkd file
    /// through the `stats_partial` executables —
    /// [`crate::coordinator::streaming`]).
    Streaming,
    /// Sharded out-of-core pure-rust engine over any
    /// [`crate::data::DataSource`] ([`crate::kmeans::streaming`]):
    /// bounded memory (`--memory-budget` / `--chunk`), bit-identical
    /// to the in-memory engines.
    OutOfCore,
    /// Multi-process distributed leader over TCP shard workers
    /// (`--workers a:p1,b:p2`, [`crate::kmeans::dist`]): each worker
    /// owns one shard, the leader folds per-shard partials with the
    /// canonical merge — bit-identical to `oocore`/`threads` at equal
    /// shard counts (DESIGN.md §10).
    Dist,
}

impl Engine {
    /// The AOT coordinator engines run their own executables, so the
    /// pure-rust distance-policy knob (DESIGN.md §11) cannot reach
    /// their hot path. Single-sourced so every validation site rejects
    /// the same set — a new engine only needs classifying once.
    pub fn supports_distance_policy(&self) -> bool {
        !matches!(self, Engine::Shared | Engine::Offload | Engine::Streaming)
    }
}

impl std::str::FromStr for Engine {
    type Err = Error;

    fn from_str(s: &str) -> Result<Engine> {
        Ok(match s {
            "serial" => Engine::Serial,
            "threads" => Engine::Threads,
            "shared" => Engine::Shared,
            "offload" => Engine::Offload,
            "elkan" => Engine::Elkan,
            "hamerly" => Engine::Hamerly,
            "minibatch" => Engine::MiniBatch,
            "streaming" => Engine::Streaming,
            "oocore" => Engine::OutOfCore,
            "dist" => Engine::Dist,
            other => {
                return Err(Error::Config(format!(
                    "unknown engine `{other}` (serial|threads|shared|offload|elkan|hamerly|minibatch|streaming|oocore|dist)"
                )))
            }
        })
    }
}

impl std::fmt::Display for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = match self {
            Engine::Serial => "serial",
            Engine::Threads => "threads",
            Engine::Shared => "shared",
            Engine::Offload => "offload",
            Engine::Elkan => "elkan",
            Engine::Hamerly => "hamerly",
            Engine::MiniBatch => "minibatch",
            Engine::Streaming => "streaming",
            Engine::OutOfCore => "oocore",
            Engine::Dist => "dist",
        };
        f.write_str(s)
    }
}

/// How the multi-threaded engines hand row chunks to workers
/// (DESIGN.md §9). Both modes are deterministic for the engines that
/// honor the chunk-granular statistics contract; `Static` is the
/// paper's contiguous decomposition, kept as the ablation baseline.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SchedMode {
    /// Contiguous per-worker shards fixed up front (the paper's OpenMP
    /// decomposition); no load balancing.
    Static,
    /// Chunk-granular work stealing: idle workers pull
    /// `POINTS_BLOCK`-aligned chunks from the tails of other workers'
    /// deques ([`crate::kmeans::sched`]). The default here and for the
    /// pruned engines (bit-identical either way); the CLI defaults the
    /// dense `threads` engine to `Static` to preserve the DESIGN.md §4
    /// `oocore ≡ threads` bit-identity.
    #[default]
    Steal,
}

impl std::str::FromStr for SchedMode {
    type Err = Error;

    fn from_str(s: &str) -> Result<SchedMode> {
        Ok(match s {
            "static" => SchedMode::Static,
            "steal" => SchedMode::Steal,
            other => {
                return Err(Error::Config(format!(
                    "unknown scheduler `{other}` (static|steal)"
                )))
            }
        })
    }
}

/// Which distributed scheduler `--engine dist` runs (DESIGN.md §10,
/// §12). The two schedulers differ in failure model *and* in f64
/// grouping: `Static` folds per-shard continuing sums (bit-identical
/// to `oocore` / `threads --sched static`), `Elastic` folds per-chunk
/// zero-seeded sums (bit-identical to `threads --sched steal`,
/// invariant under re-dispatch, retry and worker count).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DistSched {
    /// One contiguous shard per worker, fixed at connect; any worker
    /// failure aborts the run (the PR 4 baseline, and the default).
    #[default]
    Static,
    /// Chunk-granular dispatch over full-view workers with re-dispatch
    /// on failure, bounded reconnect retries, speculative re-execution
    /// of straggler chunks and mid-run worker join
    /// ([`crate::kmeans::dist::elastic`]).
    Elastic,
}

impl std::str::FromStr for DistSched {
    type Err = Error;

    fn from_str(s: &str) -> Result<DistSched> {
        Ok(match s {
            "static" => DistSched::Static,
            "elastic" => DistSched::Elastic,
            other => {
                return Err(Error::Config(format!(
                    "unknown dist scheduler `{other}` (static|elastic)"
                )))
            }
        })
    }
}

impl std::fmt::Display for DistSched {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            DistSched::Static => "static",
            DistSched::Elastic => "elastic",
        })
    }
}

impl std::fmt::Display for SchedMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SchedMode::Static => "static",
            SchedMode::Steal => "steal",
        })
    }
}

/// Centroid initialization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    /// K distinct points sampled uniformly from the data (the paper).
    Random,
    /// k-means++ D² seeding (extension, DESIGN.md A3).
    KmeansPlusPlus,
}

impl std::str::FromStr for Init {
    type Err = Error;

    fn from_str(s: &str) -> Result<Init> {
        Ok(match s {
            "random" => Init::Random,
            "kmeans++" | "kpp" => Init::KmeansPlusPlus,
            other => {
                return Err(Error::Config(format!(
                    "unknown init `{other}` (random|kmeans++)"
                )))
            }
        })
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub engine: Engine,
    pub k: usize,
    /// Convergence tolerance on E = Σ‖μ_new − μ_old‖² (paper: 1e-6).
    pub tol: f64,
    pub max_iters: usize,
    pub seed: u64,
    pub init: Init,
    /// Worker/thread count (Threads/Shared/Elkan/Hamerly engines).
    pub threads: usize,
    /// Chunk scheduler for the multi-threaded pure-rust engines
    /// (`--sched static|steal`, DESIGN.md §9). Results never depend on
    /// it for the engines under the chunk-granular contract; it is the
    /// load-balancing ablation knob.
    pub sched: SchedMode,
    /// Streaming chunk size, in rows. For the AOT engines 0 = auto
    /// (the planner combines every artifact size available for (d, k);
    /// a nonzero value pins one artifact — the A1 ablation). For the
    /// out-of-core engine this is the per-shard chunk buffer; 0 defers
    /// to [`memory_budget`](RunConfig::memory_budget) or the default.
    pub chunk: usize,
    /// Resident-memory budget in bytes for the out-of-core engine's
    /// chunk buffers (`--memory-budget`, parsed by [`parse_bytes`]).
    /// 0 = unbounded. Ignored by the in-memory engines.
    pub memory_budget: usize,
    /// Mini-batch size (MiniBatch engine only).
    pub batch: usize,
    /// Artifacts directory (AOT engines only).
    pub artifacts_dir: std::path::PathBuf,
    /// Assign/accumulate kernel tier request (`auto` resolves to the
    /// best tier the host supports; see `linalg::kernel`). A non-auto
    /// value is pinned process-wide by the coordinator engines at
    /// entry; `auto` defers to `--kernel` / `PARAKM_KERNEL` /
    /// detection.
    pub kernel: KernelChoice,
    /// Distance formulation for the pure-rust engines (`--distance`,
    /// `PARAKM_DISTANCE`; DESIGN.md §11). Defaults to
    /// [`DistancePolicy::Exact`] — the formulation every documented
    /// bit-identity contract is stated against; `dot` trades those
    /// last-ulp guarantees for the norm-trick FMA hot path.
    pub distance: DistancePolicy,
    /// Checkpoint directory (`--checkpoint`, DESIGN.md §14). `None`
    /// disables checkpointing. The sink writes two-slot A/B rotated
    /// `.pkc` snapshots so a crash mid-write never destroys the last
    /// good one.
    pub checkpoint: Option<std::path::PathBuf>,
    /// Checkpoint cadence in iterations (`--checkpoint-every`, default
    /// 1 = every iteration). Ignored unless
    /// [`checkpoint`](RunConfig::checkpoint) is set.
    pub checkpoint_every: usize,
    /// Resume directory (`--resume`): load the newest decodable `.pkc`
    /// slot, validate its run fingerprint against this config and the
    /// loaded data shape, and continue from the snapshot iteration.
    pub resume: Option<std::path::PathBuf>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            engine: Engine::Serial,
            k: 4,
            tol: 1e-6,
            max_iters: 300,
            seed: 42,
            init: Init::Random,
            threads: 4,
            sched: SchedMode::Steal,
            chunk: 0, // auto
            memory_budget: 0, // unbounded
            batch: 8192,
            artifacts_dir: "artifacts".into(),
            kernel: KernelChoice::Auto,
            distance: DistancePolicy::Exact,
            checkpoint: None,
            checkpoint_every: 1,
            resume: None,
        }
    }
}

/// Parse a byte count with an optional binary-unit suffix: `"65536"`,
/// `"64K"`, `"8M"`, `"1G"` (case-insensitive; a trailing `B`/`iB` is
/// accepted, so `64KiB` and `8mb` work). Used by `--memory-budget`.
pub fn parse_bytes(s: &str) -> Result<usize> {
    let t = s.trim();
    let lower = t.to_ascii_lowercase();
    let (digits, mult) = if let Some(d) = strip_unit(&lower, 'k') {
        (d, 1usize << 10)
    } else if let Some(d) = strip_unit(&lower, 'm') {
        (d, 1usize << 20)
    } else if let Some(d) = strip_unit(&lower, 'g') {
        (d, 1usize << 30)
    } else {
        // plain bytes, with or without a bare B suffix ("1024B")
        let body = lower.strip_suffix("ib").or_else(|| lower.strip_suffix('b')).unwrap_or(&lower);
        (body, 1usize)
    };
    let n: usize = digits
        .trim()
        .parse()
        .map_err(|_| Error::Config(format!("cannot parse byte count `{s}` (use N, NK, NM, NG)")))?;
    n.checked_mul(mult)
        .ok_or_else(|| Error::Config(format!("byte count `{s}` overflows")))
}

/// Strip a `<digits><unit>[b|ib]` suffix, returning the digit part.
fn strip_unit<'a>(lower: &'a str, unit: char) -> Option<&'a str> {
    let body = lower.strip_suffix("ib").or_else(|| lower.strip_suffix('b')).unwrap_or(lower);
    body.strip_suffix(unit)
}

impl RunConfig {
    /// Pin a non-auto kernel tier process-wide. No-op for `Auto`,
    /// which defers to `--kernel` / `PARAKM_KERNEL` / detection;
    /// errors if a different tier is already fixed or unsupported.
    pub fn pin_kernel(&self) -> Result<()> {
        if self.kernel != KernelChoice::Auto {
            crate::linalg::kernel::set_active(self.kernel)?;
        }
        Ok(())
    }

    pub fn validate(&self) -> Result<()> {
        if self.k == 0 {
            return Err(Error::Config("k must be >= 1".into()));
        }
        if self.tol < 0.0 {
            return Err(Error::Config("tol must be >= 0".into()));
        }
        if self.max_iters == 0 {
            return Err(Error::Config("max_iters must be >= 1".into()));
        }
        if self.threads == 0 {
            return Err(Error::Config("threads must be >= 1".into()));
        }
        if self.checkpoint_every == 0 {
            return Err(Error::Config("checkpoint-every must be >= 1".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn engine_parse_roundtrip() {
        for e in [
            Engine::Serial,
            Engine::Threads,
            Engine::Shared,
            Engine::Offload,
            Engine::Elkan,
            Engine::Hamerly,
            Engine::MiniBatch,
            Engine::Streaming,
            Engine::OutOfCore,
            Engine::Dist,
        ] {
            let s = e.to_string();
            assert_eq!(s.parse::<Engine>().unwrap(), e);
        }
        assert!("gpu".parse::<Engine>().is_err());
    }

    #[test]
    fn init_parse() {
        assert_eq!("random".parse::<Init>().unwrap(), Init::Random);
        assert_eq!("kpp".parse::<Init>().unwrap(), Init::KmeansPlusPlus);
        assert!("fancy".parse::<Init>().is_err());
    }

    #[test]
    fn validation() {
        let mut c = RunConfig::default();
        assert!(c.validate().is_ok());
        c.k = 0;
        assert!(c.validate().is_err());
        c = RunConfig { tol: -1.0, ..Default::default() };
        assert!(c.validate().is_err());
        c = RunConfig { threads: 0, ..Default::default() };
        assert!(c.validate().is_err());
        // chunk 0 is valid (auto)
        c = RunConfig { chunk: 0, ..Default::default() };
        assert!(c.validate().is_ok());
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("0").unwrap(), 0);
        assert_eq!(parse_bytes("65536").unwrap(), 65536);
        assert_eq!(parse_bytes("1024B").unwrap(), 1024);
        assert_eq!(parse_bytes("64K").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("64KiB").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("8m").unwrap(), 8 << 20);
        assert_eq!(parse_bytes("8MB").unwrap(), 8 << 20);
        assert_eq!(parse_bytes("1G").unwrap(), 1 << 30);
        assert_eq!(parse_bytes(" 2g ").unwrap(), 2 << 30);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("lots").is_err());
        assert!(parse_bytes("12T").is_err());
        assert!(parse_bytes("999999999999999999G").is_err());
    }

    #[test]
    fn memory_budget_defaults_unbounded() {
        assert_eq!(RunConfig::default().memory_budget, 0);
    }

    #[test]
    fn sched_mode_parses_and_defaults_to_steal() {
        assert_eq!(RunConfig::default().sched, SchedMode::Steal);
        for m in [SchedMode::Static, SchedMode::Steal] {
            assert_eq!(m.to_string().parse::<SchedMode>().unwrap(), m);
        }
        assert!("greedy".parse::<SchedMode>().is_err());
    }

    #[test]
    fn dist_sched_parses_and_defaults_to_static() {
        assert_eq!(DistSched::default(), DistSched::Static);
        for m in [DistSched::Static, DistSched::Elastic] {
            assert_eq!(m.to_string().parse::<DistSched>().unwrap(), m);
        }
        let err = "steal".parse::<DistSched>().unwrap_err();
        assert!(err.to_string().contains("static|elastic"), "{err}");
    }

    #[test]
    fn kernel_choice_defaults_to_auto_and_parses() {
        let c = RunConfig::default();
        assert_eq!(c.kernel, KernelChoice::Auto);
        assert_eq!("scalar".parse::<KernelChoice>().unwrap(), KernelChoice::Scalar);
        assert!("mmx".parse::<KernelChoice>().is_err());
    }

    #[test]
    fn aot_engines_do_not_support_the_distance_policy_knob() {
        for e in [Engine::Shared, Engine::Offload, Engine::Streaming] {
            assert!(!e.supports_distance_policy(), "{e}");
        }
        for e in [
            Engine::Serial,
            Engine::Threads,
            Engine::Elkan,
            Engine::Hamerly,
            Engine::MiniBatch,
            Engine::OutOfCore,
            Engine::Dist,
        ] {
            assert!(e.supports_distance_policy(), "{e}");
        }
    }

    #[test]
    fn checkpoint_defaults_off_and_cadence_validated() {
        let c = RunConfig::default();
        assert!(c.checkpoint.is_none());
        assert!(c.resume.is_none());
        assert_eq!(c.checkpoint_every, 1);
        let bad = RunConfig { checkpoint_every: 0, ..Default::default() };
        assert!(bad.validate().is_err());
    }

    #[test]
    fn distance_defaults_to_exact_and_parses() {
        // Exact is load-bearing: every bit-identity pin assumes it
        assert_eq!(RunConfig::default().distance, DistancePolicy::Exact);
        assert_eq!("dot".parse::<DistancePolicy>().unwrap(), DistancePolicy::Dot);
        assert!("euclid".parse::<DistancePolicy>().is_err());
    }
}
