//! Mini property-testing framework (proptest is unavailable offline —
//! DESIGN.md §8).
//!
//! Deliberately small: seeded generators + a fixed-iteration runner with
//! linear input shrinking. Usage:
//!
//! ```
//! use parakmeans::testutil::prop;
//! prop::check("sum commutes", 64, |g| {
//!     let a = g.usize_in(0, 100);
//!     let b = g.usize_in(0, 100);
//!     prop::ensure(a + b == b + a, format!("a={a} b={b}"))
//! });
//! ```

use crate::kmeans::KmeansResult;

/// Assert two engine results are bit-identical — the chunked-
/// accumulation contract's definition of equality, single-sourced for
/// the unit, integration and bench cross-checks: assignments, centroid
/// bits, SSE bits, convergence telemetry and the full per-iteration
/// history.
///
/// Panics with `what` context on the first divergence.
pub fn assert_bit_identical(a: &KmeansResult, b: &KmeansResult, what: &str) {
    assert_eq!(a.iterations, b.iterations, "{what}: iterations");
    assert_eq!(a.converged, b.converged, "{what}: converged");
    assert_eq!(a.assign, b.assign, "{what}: assignments");
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a.centroids), bits(&b.centroids), "{what}: centroid bits");
    assert_eq!(a.sse.to_bits(), b.sse.to_bits(), "{what}: sse bits");
    assert_eq!(a.history.len(), b.history.len(), "{what}: history length");
    for (i, (x, y)) in a.history.iter().zip(&b.history).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{what}: history[{i}].sse");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{what}: history[{i}].shift");
    }
}

pub mod prop {
    use crate::rng::Pcg64;

    /// Seeded input generator handed to each property iteration.
    pub struct Gen {
        rng: Pcg64,
        /// Shrink factor in (0, 1]; generators scale their ranges by it
        /// so re-runs after a failure probe smaller inputs.
        pub scale: f64,
    }

    impl Gen {
        pub fn new(seed: u64) -> Gen {
            Gen { rng: Pcg64::new(seed, 0x9E), scale: 1.0 }
        }

        pub fn u64(&mut self) -> u64 {
            self.rng.next_u64()
        }

        pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
            lo + self.rng.next_f64() * (hi - lo)
        }

        pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
            lo + self.rng.next_f32() * (hi - lo)
        }

        /// Integer in [lo, hi] inclusive, range scaled by `scale`.
        pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
            assert!(hi >= lo);
            let span = ((hi - lo) as f64 * self.scale).ceil() as u64 + 1;
            lo + self.rng.next_below(span) as usize
        }

        pub fn bool(&mut self) -> bool {
            self.rng.next_u64() & 1 == 1
        }

        pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
            &items[self.rng.next_below(items.len() as u64) as usize]
        }

        /// Vector of f32 points (row-major n×d) roughly in [-spread, spread].
        pub fn points(&mut self, n: usize, d: usize, spread: f32) -> Vec<f32> {
            (0..n * d).map(|_| self.f32_in(-spread, spread)).collect()
        }

        /// One uniformly random byte.
        pub fn byte(&mut self) -> u8 {
            (self.rng.next_u64() & 0xFF) as u8
        }

        /// `n` uniformly random bytes (fuzz soup).
        pub fn bytes(&mut self, n: usize) -> Vec<u8> {
            (0..n).map(|_| self.byte()).collect()
        }

        /// `n` bytes drawn from `alphabet` — structured soup (e.g. JSON
        /// punctuation) that reaches deeper parser states than uniform
        /// bytes do.
        pub fn ascii_soup(&mut self, n: usize, alphabet: &[u8]) -> Vec<u8> {
            assert!(!alphabet.is_empty());
            (0..n).map(|_| *self.choice(alphabet)).collect()
        }

        /// Apply `edits` random mutations in place: bit flips, byte
        /// overwrites, insertions, deletions and tail truncations — the
        /// standard corruption menu for fuzzing a valid input.
        pub fn mutate(&mut self, buf: &mut Vec<u8>, edits: usize) {
            for _ in 0..edits {
                match self.rng.next_below(5) {
                    0 if !buf.is_empty() => {
                        // flip one bit
                        let i = self.rng.next_below(buf.len() as u64) as usize;
                        buf[i] ^= 1 << (self.rng.next_u64() & 7);
                    }
                    1 if !buf.is_empty() => {
                        // overwrite one byte
                        let i = self.rng.next_below(buf.len() as u64) as usize;
                        buf[i] = self.byte();
                    }
                    2 => {
                        // insert one byte
                        let i = self.rng.next_below(buf.len() as u64 + 1) as usize;
                        buf.insert(i, self.byte());
                    }
                    3 if !buf.is_empty() => {
                        // delete one byte
                        let i = self.rng.next_below(buf.len() as u64) as usize;
                        buf.remove(i);
                    }
                    _ if !buf.is_empty() => {
                        // truncate the tail
                        let keep = self.rng.next_below(buf.len() as u64) as usize;
                        buf.truncate(keep);
                    }
                    _ => buf.push(self.byte()),
                }
            }
        }
    }

    /// A property outcome: `Ok(())` passes, `Err(msg)` fails with context.
    pub type Outcome = Result<(), String>;

    /// Convenience assertion.
    pub fn ensure(cond: bool, msg: impl Into<String>) -> Outcome {
        if cond {
            Ok(())
        } else {
            Err(msg.into())
        }
    }

    /// Run `iters` iterations of `prop`. On failure, retry with
    /// progressively smaller `scale` (shrink-lite) to report the
    /// smallest failing seed/scale found, then panic with context.
    pub fn check(name: &str, iters: u64, mut prop: impl FnMut(&mut Gen) -> Outcome) {
        // Seed derives from the property name so adding properties does
        // not perturb existing ones; PARAKM_PROP_SEED overrides.
        let base = std::env::var("PARAKM_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or_else(|| {
                name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
                    (h ^ b as u64).wrapping_mul(0x100000001b3)
                })
            });
        for i in 0..iters {
            let seed = base.wrapping_add(i);
            let mut g = Gen::new(seed);
            if let Err(msg) = prop(&mut g) {
                // shrink: same seed, smaller scales
                let mut smallest = (1.0f64, msg.clone());
                for &s in &[0.5, 0.25, 0.1, 0.05, 0.01] {
                    let mut g = Gen::new(seed);
                    g.scale = s;
                    if let Err(m) = prop(&mut g) {
                        smallest = (s, m);
                    }
                }
                panic!(
                    "property `{name}` failed (seed={seed}, iter={i}):\n  at scale 1.0: {msg}\n  smallest failing scale {}: {}",
                    smallest.0, smallest.1
                );
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::prop;

    #[test]
    fn passing_property_runs_all_iters() {
        let mut count = 0;
        prop::check("always true", 32, |_| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic(expected = "property `always false` failed")]
    fn failing_property_panics_with_context() {
        prop::check("always false", 8, |_| prop::ensure(false, "nope"));
    }

    #[test]
    fn generators_respect_ranges() {
        prop::check("ranges", 64, |g| {
            let v = g.usize_in(5, 10);
            prop::ensure((5..=10).contains(&v), format!("usize_in out of range: {v}"))?;
            let f = g.f64_in(-1.0, 1.0);
            prop::ensure((-1.0..1.0).contains(&f), format!("f64_in out of range: {f}"))?;
            let c = *g.choice(&[1, 2, 3]);
            prop::ensure([1, 2, 3].contains(&c), "choice outside set")
        });
    }

    #[test]
    fn points_shape() {
        let mut g = prop::Gen::new(1);
        let pts = g.points(7, 3, 2.0);
        assert_eq!(pts.len(), 21);
        assert!(pts.iter().all(|v| v.abs() <= 2.0));
    }

    #[test]
    fn deterministic_given_seed() {
        let mut a = prop::Gen::new(9);
        let mut b = prop::Gen::new(9);
        for _ in 0..16 {
            assert_eq!(a.u64(), b.u64());
        }
    }

    #[test]
    fn byte_generators_are_deterministic_and_shaped() {
        let mut a = prop::Gen::new(11);
        let mut b = prop::Gen::new(11);
        assert_eq!(a.bytes(64), b.bytes(64));
        let soup = a.ascii_soup(128, b"{}[],:\"x");
        assert_eq!(soup.len(), 128);
        assert!(soup.iter().all(|c| b"{}[],:\"x".contains(c)));
    }

    #[test]
    fn mutate_changes_but_never_panics() {
        prop::check("mutate stays total", 128, |g| {
            let mut buf = g.bytes(g.usize_in(0, 64));
            let edits = g.usize_in(0, 16);
            g.mutate(&mut buf, edits);
            prop::ensure(buf.len() <= 64 + edits, "mutation grew past the edit budget")
        });
    }
}
