//! Crate-wide error type.
//!
//! Library modules return [`Result`]; binaries/examples may wrap it in
//! `anyhow` for context chaining. The XLA runtime variant boxes the
//! `xla` crate error to keep this enum `Send + Sync`.

use thiserror::Error;

/// All errors produced by parakmeans.
#[derive(Debug, Error)]
pub enum Error {
    /// Malformed or missing AOT artifact manifest.
    #[error("manifest error: {0}")]
    Manifest(String),

    /// JSON syntax error while parsing (path context in the message).
    #[error("json parse error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    /// Shape/dimension mismatch between datasets, centroids, artifacts.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Invalid configuration (CLI or programmatic).
    #[error("invalid config: {0}")]
    Config(String),

    /// Underlying XLA/PJRT failure.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Dataset / file IO.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// A worker thread panicked or disconnected.
    #[error("worker failure: {0}")]
    Worker(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
