//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline image ships no
//! `thiserror`/`anyhow` (DESIGN.md §8). The `Xla` variant is kept for
//! a future real-PJRT backend; the in-crate native executor
//! (`runtime::native`) reports through the other variants.

/// All errors produced by parakmeans.
#[derive(Debug)]
pub enum Error {
    /// Malformed or missing AOT artifact manifest.
    Manifest(String),

    /// JSON syntax error while parsing (path context in the message).
    Json { offset: usize, message: String },

    /// Shape/dimension mismatch between datasets, centroids, artifacts.
    Shape(String),

    /// Invalid configuration (CLI or programmatic).
    Config(String),

    /// Malformed dataset content: bad magic bytes, a truncated binary
    /// payload or truth section, a ragged or non-numeric CSV row, or a
    /// source that violated its chunk contract. Distinct from [`Error::Io`]
    /// (the OS failed to read) — here the bytes arrived but are wrong.
    Data(String),

    /// Underlying XLA/PJRT failure (real-PJRT backend only).
    Xla(String),

    /// Dataset / file IO.
    Io(std::io::Error),

    /// A worker thread panicked or disconnected.
    Worker(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Config(m) => write!(f, "invalid config: {m}"),
            Error::Data(m) => write!(f, "malformed data: {m}"),
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Worker(m) => write!(f, "worker failure: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        assert_eq!(Error::Manifest("x".into()).to_string(), "manifest error: x");
        assert_eq!(
            Error::Json { offset: 7, message: "bad".into() }.to_string(),
            "json parse error at byte 7: bad"
        );
        assert_eq!(Error::Config("k".into()).to_string(), "invalid config: k");
        assert_eq!(Error::Data("short".into()).to_string(), "malformed data: short");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
