//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls — the offline image ships no
//! `thiserror`/`anyhow` (DESIGN.md §8). The `Xla` variant is kept for
//! a future real-PJRT backend; the in-crate native executor
//! (`runtime::native`) reports through the other variants.

/// What went wrong between a distributed leader and its shard workers
/// (DESIGN.md §10). Carried by [`Error::Cluster`]; the variants are the
/// failure model the leader's fail-fast contract is tested against:
/// every one must surface promptly (bounded read timeouts), never hang.
#[derive(Debug)]
pub enum ClusterError {
    /// TCP connect/read/write failed, timed out, or the peer hung up —
    /// the bytes never arrived.
    Connection(String),

    /// Bytes arrived but do not form a valid frame: bad length prefix,
    /// unknown frame type, truncated or overlong payload.
    Frame(String),

    /// Peers disagree on shapes: shard dimensionality, centroid k×d,
    /// assignment length vs the advertised shard size.
    Shape(String),

    /// A well-formed frame at the wrong point in the conversation, or
    /// a failure the worker reported in an `ErrMsg` frame.
    Protocol(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Connection(m) => write!(f, "connection: {m}"),
            ClusterError::Frame(m) => write!(f, "bad frame: {m}"),
            ClusterError::Shape(m) => write!(f, "shape: {m}"),
            ClusterError::Protocol(m) => write!(f, "protocol: {m}"),
        }
    }
}

/// All errors produced by parakmeans.
#[derive(Debug)]
pub enum Error {
    /// Malformed or missing AOT artifact manifest.
    Manifest(String),

    /// JSON syntax error while parsing (path context in the message).
    Json { offset: usize, message: String },

    /// Shape/dimension mismatch between datasets, centroids, artifacts.
    Shape(String),

    /// Invalid configuration (CLI or programmatic).
    Config(String),

    /// Malformed dataset content: bad magic bytes, a truncated binary
    /// payload or truth section, a ragged or non-numeric CSV row, or a
    /// source that violated its chunk contract. Distinct from [`Error::Io`]
    /// (the OS failed to read) — here the bytes arrived but are wrong.
    Data(String),

    /// Underlying XLA/PJRT failure (real-PJRT backend only).
    Xla(String),

    /// Dataset / file IO.
    Io(std::io::Error),

    /// A worker thread panicked or disconnected.
    Worker(String),

    /// Distributed leader/worker failure ([`ClusterError`] taxonomy:
    /// connection loss, frame corruption, shape mismatch, protocol
    /// violation — DESIGN.md §10).
    Cluster(ClusterError),

    /// Checkpoint/resume failure (DESIGN.md §14): a corrupt or
    /// truncated `.pkc` snapshot, a CRC mismatch, or a fingerprint
    /// that does not match the resuming run's configuration (wrong
    /// seed/engine/data shape must fail loudly, never resume wrong).
    Ckpt(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::Manifest(m) => write!(f, "manifest error: {m}"),
            Error::Json { offset, message } => {
                write!(f, "json parse error at byte {offset}: {message}")
            }
            Error::Shape(m) => write!(f, "shape mismatch: {m}"),
            Error::Config(m) => write!(f, "invalid config: {m}"),
            Error::Data(m) => write!(f, "malformed data: {m}"),
            Error::Xla(m) => write!(f, "xla runtime: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Worker(m) => write!(f, "worker failure: {m}"),
            Error::Cluster(e) => write!(f, "cluster: {e}"),
            Error::Ckpt(m) => write!(f, "checkpoint: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_messages_are_stable() {
        assert_eq!(Error::Manifest("x".into()).to_string(), "manifest error: x");
        assert_eq!(
            Error::Json { offset: 7, message: "bad".into() }.to_string(),
            "json parse error at byte 7: bad"
        );
        assert_eq!(Error::Config("k".into()).to_string(), "invalid config: k");
        assert_eq!(Error::Data("short".into()).to_string(), "malformed data: short");
        assert_eq!(
            Error::Cluster(ClusterError::Connection("gone".into())).to_string(),
            "cluster: connection: gone"
        );
        assert_eq!(
            Error::Cluster(ClusterError::Frame("len".into())).to_string(),
            "cluster: bad frame: len"
        );
        assert_eq!(
            Error::Cluster(ClusterError::Shape("dim".into())).to_string(),
            "cluster: shape: dim"
        );
        assert_eq!(
            Error::Cluster(ClusterError::Protocol("order".into())).to_string(),
            "cluster: protocol: order"
        );
        assert_eq!(Error::Ckpt("stale".into()).to_string(), "checkpoint: stale");
    }

    #[test]
    fn io_errors_convert_and_chain() {
        let e: Error = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(e.to_string().contains("gone"));
        assert!(std::error::Error::source(&e).is_some());
    }
}
