//! Clustering-quality and parallel-performance metrics.
//!
//! Quality: SSE/inertia (the paper's objective), Adjusted Rand Index and
//! NMI (used instead of the paper's eyeball comparison of Figures 1–6 to
//! check serial == parallel clustering), sampled silhouette.
//! Performance: speedup ψ(n,p) and efficiency ε(n,p) exactly as the
//! paper defines them (Figures 7–10).

pub mod indices;

pub use indices::{calinski_harabasz, davies_bouldin};

use std::collections::HashMap;

use crate::data::Dataset;
use crate::linalg;

/// Sum of squared distances of each point to its assigned centroid
/// (the K-Means objective; f64 accumulation for 1M-point stability).
pub fn sse(ds: &Dataset, centroids: &[f32], k: usize, assign: &[i32]) -> f64 {
    assert_eq!(assign.len(), ds.len());
    assert_eq!(centroids.len(), k * ds.dim());
    let d = ds.dim();
    let mut total = 0.0f64;
    for i in 0..ds.len() {
        let a = assign[i];
        if a < 0 {
            continue;
        }
        let c = &centroids[(a as usize) * d..(a as usize + 1) * d];
        total += linalg::sqdist_f64(ds.point(i), c);
    }
    total
}

/// Contingency table between two labelings (ignores negative labels).
type Contingency = (HashMap<(i32, i32), u64>, HashMap<i32, u64>, HashMap<i32, u64>, u64);

fn contingency(a: &[i32], b: &[i32]) -> Contingency {
    assert_eq!(a.len(), b.len());
    let mut joint: HashMap<(i32, i32), u64> = HashMap::new();
    let mut ma: HashMap<i32, u64> = HashMap::new();
    let mut mb: HashMap<i32, u64> = HashMap::new();
    let mut n = 0u64;
    for (&x, &y) in a.iter().zip(b) {
        if x < 0 || y < 0 {
            continue;
        }
        *joint.entry((x, y)).or_default() += 1;
        *ma.entry(x).or_default() += 1;
        *mb.entry(y).or_default() += 1;
        n += 1;
    }
    (joint, ma, mb, n)
}

fn comb2(x: u64) -> f64 {
    (x as f64) * ((x as f64) - 1.0) / 2.0
}

/// Adjusted Rand Index ∈ [-1, 1]; 1 ⇔ identical partitions.
pub fn adjusted_rand_index(a: &[i32], b: &[i32]) -> f64 {
    let (joint, ma, mb, n) = contingency(a, b);
    if n < 2 {
        return 1.0;
    }
    let sum_ij: f64 = joint.values().map(|&c| comb2(c)).sum();
    let sum_a: f64 = ma.values().map(|&c| comb2(c)).sum();
    let sum_b: f64 = mb.values().map(|&c| comb2(c)).sum();
    let total = comb2(n);
    let expected = sum_a * sum_b / total;
    let max_idx = 0.5 * (sum_a + sum_b);
    if (max_idx - expected).abs() < 1e-12 {
        return 1.0;
    }
    (sum_ij - expected) / (max_idx - expected)
}

/// Normalized Mutual Information ∈ [0, 1] (arithmetic-mean normalizer).
pub fn nmi(a: &[i32], b: &[i32]) -> f64 {
    let (joint, ma, mb, n) = contingency(a, b);
    if n == 0 {
        return 1.0;
    }
    let nf = n as f64;
    let mut mi = 0.0;
    for (&(x, y), &c) in &joint {
        let pxy = c as f64 / nf;
        let px = ma[&x] as f64 / nf;
        let py = mb[&y] as f64 / nf;
        mi += pxy * (pxy / (px * py)).ln();
    }
    let h = |m: &HashMap<i32, u64>| -> f64 {
        m.values()
            .map(|&c| {
                let p = c as f64 / nf;
                -p * p.ln()
            })
            .sum()
    };
    let (ha, hb) = (h(&ma), h(&mb));
    if ha == 0.0 && hb == 0.0 {
        return 1.0; // both single-cluster: identical
    }
    let denom = 0.5 * (ha + hb);
    if denom == 0.0 {
        return 0.0;
    }
    (mi / denom).clamp(0.0, 1.0)
}

/// Silhouette coefficient averaged over a deterministic sample of at
/// most `sample` points (full silhouette is O(n²); the sampled variant
/// is the standard big-data compromise).
pub fn silhouette_sampled(ds: &Dataset, assign: &[i32], k: usize, sample: usize, seed: u64) -> f64 {
    let n = ds.len();
    assert_eq!(assign.len(), n);
    if n == 0 || k < 2 {
        return 0.0;
    }
    let mut rng = crate::rng::Pcg64::new(seed, 0x51);
    let idx: Vec<usize> = if n <= sample {
        (0..n).collect()
    } else {
        rng.sample_indices(n, sample)
    };
    let mut total = 0.0f64;
    let mut counted = 0usize;
    for &i in &idx {
        let ai = assign[i];
        if ai < 0 {
            continue;
        }
        // mean distance to every cluster (over the sampled pool, against
        // all points for exactness would be O(n) per point — acceptable
        // only for the sample)
        let mut sums = vec![0.0f64; k];
        let mut counts = vec![0u64; k];
        for j in 0..n {
            if j == i || assign[j] < 0 {
                continue;
            }
            let c = assign[j] as usize;
            sums[c] += linalg::sqdist_f64(ds.point(i), ds.point(j)).sqrt();
            counts[c] += 1;
        }
        let own = ai as usize;
        if counts[own] == 0 {
            continue;
        }
        let a_val = sums[own] / counts[own] as f64;
        let b_val = (0..k)
            .filter(|&c| c != own && counts[c] > 0)
            .map(|c| sums[c] / counts[c] as f64)
            .fold(f64::INFINITY, f64::min);
        if !b_val.is_finite() {
            continue;
        }
        total += (b_val - a_val) / a_val.max(b_val);
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

/// Speedup ψ(n, p) = T_serial / T_parallel (paper Figures 7/8).
pub fn speedup(t_serial: f64, t_parallel: f64) -> f64 {
    assert!(t_parallel > 0.0);
    t_serial / t_parallel
}

/// Efficiency ε(n, p) = ψ(n, p) / p (paper Figures 9/10).
pub fn efficiency(t_serial: f64, t_parallel: f64, p: usize) -> f64 {
    speedup(t_serial, t_parallel) / p as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;

    #[test]
    fn sse_basic() {
        let ds = Dataset::from_vec(vec![0.0, 0.0, 2.0, 0.0], 2).unwrap();
        let centroids = vec![0.0, 0.0, 1.0, 0.0];
        let v = sse(&ds, &centroids, 2, &[0, 1]);
        assert_eq!(v, 1.0);
        // negative assignment skipped
        assert_eq!(sse(&ds, &centroids, 2, &[0, -1]), 0.0);
    }

    #[test]
    fn ari_identical_permuted_random() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((adjusted_rand_index(&a, &a) - 1.0).abs() < 1e-12);
        let permuted = vec![2, 2, 0, 0, 1, 1]; // same partition, renamed
        assert!((adjusted_rand_index(&a, &permuted) - 1.0).abs() < 1e-12);
        let b = vec![0, 1, 0, 1, 0, 1];
        assert!(adjusted_rand_index(&a, &b) < 0.2);
    }

    #[test]
    fn ari_tiny_input() {
        assert_eq!(adjusted_rand_index(&[0], &[0]), 1.0);
        assert_eq!(adjusted_rand_index(&[], &[]), 1.0);
    }

    #[test]
    fn nmi_identical_and_independent() {
        let a = vec![0, 0, 1, 1];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        let perm = vec![1, 1, 0, 0];
        assert!((nmi(&a, &perm) - 1.0).abs() < 1e-12);
        // one side constant, other split: MI = 0
        assert!(nmi(&[0, 0, 0, 0], &a) < 1e-12);
    }

    #[test]
    fn silhouette_separated_vs_mixed() {
        // two tight, far-apart blobs => silhouette near 1 with correct labels
        let mut data = Vec::new();
        for i in 0..20 {
            data.extend([i as f32 * 0.01, 0.0]);
        }
        for i in 0..20 {
            data.extend([100.0 + i as f32 * 0.01, 0.0]);
        }
        let ds = Dataset::from_vec(data, 2).unwrap();
        let good: Vec<i32> = (0..40).map(|i| (i >= 20) as i32).collect();
        let s_good = silhouette_sampled(&ds, &good, 2, 40, 1);
        assert!(s_good > 0.95, "{s_good}");
        // scrambled labels => poor silhouette
        let bad: Vec<i32> = (0..40).map(|i| (i % 2) as i32).collect();
        let s_bad = silhouette_sampled(&ds, &bad, 2, 40, 1);
        assert!(s_bad < 0.1, "{s_bad}");
    }

    #[test]
    fn speedup_efficiency() {
        assert_eq!(speedup(10.0, 2.5), 4.0);
        assert_eq!(efficiency(10.0, 2.5, 8), 0.5);
    }
}
