//! Internal cluster-validity indices: Davies–Bouldin and
//! Calinski–Harabasz.
//!
//! Both are O(n·d + k²·d) — cheap enough to compute exactly even at
//! the paper's 1M-point scale — and complement the sampled silhouette
//! for K-selection ([`crate::kmeans::kselect`]) and quality reporting.

use crate::data::Dataset;
use crate::linalg;

/// Per-cluster means and scatter needed by both indices.
struct ClusterStats {
    dim: usize,
    /// k×d centroids (means of the *assigned* points).
    means: Vec<f64>,
    counts: Vec<u64>,
    /// Mean distance of members to their centroid (for DB).
    dispersion: Vec<f64>,
    /// Within-cluster sum of squares (for CH).
    wss: f64,
    /// Global mean.
    global: Vec<f64>,
    n: u64,
}

fn cluster_stats(ds: &Dataset, assign: &[i32], k: usize) -> ClusterStats {
    let d = ds.dim();
    let mut means = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut global = vec![0.0f64; d];
    let mut n = 0u64;
    for i in 0..ds.len() {
        let a = assign[i];
        if a < 0 {
            continue;
        }
        let p = ds.point(i);
        linalg::add_assign(&mut means[(a as usize) * d..(a as usize + 1) * d], p);
        linalg::add_assign(&mut global, p);
        counts[a as usize] += 1;
        n += 1;
    }
    for c in 0..k {
        if counts[c] > 0 {
            for j in 0..d {
                means[c * d + j] /= counts[c] as f64;
            }
        }
    }
    if n > 0 {
        for v in global.iter_mut() {
            *v /= n as f64;
        }
    }
    let means_f32: Vec<f32> = means.iter().map(|&v| v as f32).collect();
    let mut dispersion = vec![0.0f64; k];
    let mut wss = 0.0f64;
    for i in 0..ds.len() {
        let a = assign[i];
        if a < 0 {
            continue;
        }
        let c = a as usize;
        let d2 = linalg::sqdist_f64(ds.point(i), &means_f32[c * d..(c + 1) * d]);
        dispersion[c] += d2.sqrt();
        wss += d2;
    }
    for c in 0..k {
        if counts[c] > 0 {
            dispersion[c] /= counts[c] as f64;
        }
    }
    ClusterStats { dim: d, means, counts, dispersion, wss, global, n }
}

/// Davies–Bouldin index (lower is better; 0 is ideal).
pub fn davies_bouldin(ds: &Dataset, assign: &[i32], k: usize) -> f64 {
    assert_eq!(assign.len(), ds.len());
    if k < 2 {
        return 0.0;
    }
    let st = cluster_stats(ds, assign, k);
    let d = st.dim;
    let mut total = 0.0;
    let mut counted = 0usize;
    for i in 0..k {
        if st.counts[i] == 0 {
            continue;
        }
        let mut worst: f64 = 0.0;
        for j in 0..k {
            if i == j || st.counts[j] == 0 {
                continue;
            }
            let mi: Vec<f32> = st.means[i * d..(i + 1) * d].iter().map(|&v| v as f32).collect();
            let mj: Vec<f32> = st.means[j * d..(j + 1) * d].iter().map(|&v| v as f32).collect();
            let between = linalg::sqdist_f64(&mi, &mj).sqrt();
            if between > 0.0 {
                worst = worst.max((st.dispersion[i] + st.dispersion[j]) / between);
            }
        }
        total += worst;
        counted += 1;
    }
    if counted == 0 {
        0.0
    } else {
        total / counted as f64
    }
}

/// Calinski–Harabasz index (higher is better).
pub fn calinski_harabasz(ds: &Dataset, assign: &[i32], k: usize) -> f64 {
    assert_eq!(assign.len(), ds.len());
    let st = cluster_stats(ds, assign, k);
    if k < 2 || st.n <= k as u64 || st.wss == 0.0 {
        return 0.0;
    }
    let d = st.dim;
    let global_f32: Vec<f32> = st.global.iter().map(|&v| v as f32).collect();
    let mut bss = 0.0f64;
    for c in 0..k {
        if st.counts[c] == 0 {
            continue;
        }
        let mc: Vec<f32> = st.means[c * d..(c + 1) * d].iter().map(|&v| v as f32).collect();
        bss += st.counts[c] as f64 * linalg::sqdist_f64(&mc, &global_f32);
    }
    (bss / (k as f64 - 1.0)) / (st.wss / (st.n as f64 - k as f64))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::kmeans::{self, KmeansConfig};

    fn clustered() -> (Dataset, Vec<i32>, Vec<i32>) {
        // well-separated blobs: good labels = truth, bad = scrambled
        let spec = MixtureSpec::random(2, 4, 80.0, 0.5, 3);
        let ds = spec.generate(2000, 1);
        let good = ds.truth.clone().unwrap();
        let bad: Vec<i32> = (0..2000).map(|i| (i % 4) as i32).collect();
        (ds, good, bad)
    }

    #[test]
    fn db_lower_for_better_clustering() {
        let (ds, good, bad) = clustered();
        let db_good = davies_bouldin(&ds, &good, 4);
        let db_bad = davies_bouldin(&ds, &bad, 4);
        assert!(db_good < 0.2, "good clustering DB {db_good}");
        assert!(db_bad > db_good * 5.0, "bad {db_bad} vs good {db_good}");
    }

    #[test]
    fn ch_higher_for_better_clustering() {
        let (ds, good, bad) = clustered();
        let ch_good = calinski_harabasz(&ds, &good, 4);
        let ch_bad = calinski_harabasz(&ds, &bad, 4);
        assert!(ch_good > ch_bad * 10.0, "good {ch_good} vs bad {ch_bad}");
    }

    #[test]
    fn degenerate_cases() {
        let ds = MixtureSpec::paper_2d(4).generate(50, 1);
        let one = vec![0i32; 50];
        assert_eq!(davies_bouldin(&ds, &one, 1), 0.0);
        assert_eq!(calinski_harabasz(&ds, &one, 1), 0.0);
        // negative labels ignored
        let mut part = one.clone();
        part[0] = -1;
        let _ = davies_bouldin(&ds, &part, 1);
    }

    #[test]
    fn tracks_kmeans_quality_across_k() {
        // CH should peak near the true K=4 on a crisp mixture
        let spec = MixtureSpec::random(2, 4, 70.0, 0.5, 9);
        let ds = spec.generate(1500, 2);
        let ch: Vec<f64> = [2usize, 4, 8]
            .iter()
            .map(|&k| {
                let r = kmeans::serial::run(
                    &ds,
                    &KmeansConfig::new(k)
                        .with_seed(3)
                        .with_init(crate::config::Init::KmeansPlusPlus),
                );
                calinski_harabasz(&ds, &r.assign, k)
            })
            .collect();
        assert!(ch[1] > ch[0], "CH(4) {} !> CH(2) {}", ch[1], ch[0]);
        assert!(ch[1] > ch[2], "CH(4) {} !> CH(8) {}", ch[1], ch[2]);
    }
}
