//! In-process loopback cluster: spawn shard workers as threads on
//! `127.0.0.1:0` so the full wire protocol — sockets, frames, timeouts
//! — is exercised inside `cargo test` and `cargo bench` with no
//! multi-machine infrastructure (DESIGN.md §10).
//!
//! Each worker thread serves exactly one leader session and exits; the
//! harness is therefore single-shot — spawn, run the leader, [`join`]
//! to propagate worker-side errors. Shards are the contiguous
//! [`shard_ranges`] decomposition, the same one `oocore` and the static
//! threaded engine use, so a loopback `dist(S)` run is comparable
//! bit-for-bit with `threads(p = S)` and `oocore(shards = S)`.
//!
//! For the **elastic** scheduler (DESIGN.md §12) the harness offers
//! [`LoopbackCluster::spawn_replicated`]: every worker owns a full copy
//! of the dataset (the replicated-input deployment OPERATIONS.md
//! describes), making it chunk-capable. Its
//! [`LoopbackCluster::spawn_replicated_faulty`] variant scripts
//! per-worker crashes and stalls ([`SessionFault`]) and serves a
//! bounded number of sessions per worker, so failure drills — kill,
//! stall, rejoin — run deterministically inside `cargo test`.
//!
//! [`join`]: LoopbackCluster::join

use std::net::TcpListener;
use std::time::{Duration, Instant};

use crate::cluster::worker::{SessionFault, ShardWorker};
use crate::data::dataset::shard_ranges;
use crate::data::source::OwnedMemorySource;
use crate::data::Dataset;
use crate::error::{Error, Result};

/// Per-worker script for [`LoopbackCluster::spawn_replicated_faulty`]:
/// the fault injected into the worker's *first* session, and how many
/// sessions it serves in total (rejoin drills need ≥ 2 — the elastic
/// leader reconnects after the scripted failure).
#[derive(Debug, Clone, Copy)]
pub struct WorkerDrill {
    /// Misbehavior for session 1; later sessions serve cleanly.
    pub fault: SessionFault,
    /// Sessions to serve before the thread exits (min 1). Threads stop
    /// waiting for further sessions after an accept deadline, so a
    /// leader that never reconnects cannot hang [`join`].
    ///
    /// [`join`]: LoopbackCluster::join
    pub sessions: usize,
}

impl Default for WorkerDrill {
    fn default() -> Self {
        WorkerDrill { fault: SessionFault::default(), sessions: 1 }
    }
}

impl WorkerDrill {
    fn is_faulty(&self) -> bool {
        self.fault.die_after_chunks.is_some() || self.fault.stall_after_chunks.is_some()
    }
}

/// Handle to a set of loopback worker threads.
pub struct LoopbackCluster {
    /// Worker addresses in ascending shard order — pass to
    /// [`crate::kmeans::dist::run`] as-is.
    pub addrs: Vec<String>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl LoopbackCluster {
    /// Bind each worker to an ephemeral localhost port and serve one
    /// leader session on its own thread. `workers[i]` is shard `i`.
    pub fn spawn(workers: Vec<ShardWorker>) -> Result<LoopbackCluster> {
        if workers.is_empty() {
            return Err(Error::Config("loopback: need at least one worker".into()));
        }
        // bind every listener BEFORE spawning any thread: addresses are
        // known up front, the leader cannot race a listener into
        // existence, and a bind failure (port exhaustion) errors out
        // cleanly instead of leaking already-spawned accept() threads
        let mut addrs = Vec::with_capacity(workers.len());
        let mut listeners = Vec::with_capacity(workers.len());
        for _ in &workers {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            addrs.push(listener.local_addr()?.to_string());
            listeners.push(listener);
        }
        let handles = workers
            .into_iter()
            .zip(listeners)
            .map(|(w, listener)| std::thread::spawn(move || w.serve_listener(&listener, true)))
            .collect();
        Ok(LoopbackCluster { addrs, handles })
    }

    /// Spawn `shards` workers over contiguous [`shard_ranges`] slices
    /// of `ds` (each worker owns a copy of its rows — process-boundary
    /// semantics, even in-process). `chunk_rows` never affects results.
    pub fn spawn_dataset(
        ds: &Dataset,
        shards: usize,
        chunk_rows: usize,
    ) -> Result<LoopbackCluster> {
        if shards == 0 {
            return Err(Error::Config("loopback: shards must be >= 1".into()));
        }
        let mut workers = Vec::with_capacity(shards);
        for (lo, hi) in shard_ranges(ds.len(), shards) {
            let shard = Dataset::from_vec(ds.rows(lo, hi).to_vec(), ds.dim())?;
            workers.push(ShardWorker::new(Box::new(OwnedMemorySource::new(shard)), chunk_rows)?);
        }
        LoopbackCluster::spawn(workers)
    }

    /// Spawn `workers` chunk-capable workers, each owning a **full
    /// copy** of `ds` — the replicated-input deployment the elastic
    /// scheduler requires (any worker can compute any chunk).
    pub fn spawn_replicated(
        ds: &Dataset,
        workers: usize,
        chunk_rows: usize,
    ) -> Result<LoopbackCluster> {
        LoopbackCluster::spawn_replicated_faulty(
            ds,
            chunk_rows,
            &vec![WorkerDrill::default(); workers],
        )
    }

    /// [`LoopbackCluster::spawn_replicated`] with a per-worker
    /// [`WorkerDrill`] — the failure-drill harness. A drilled worker's
    /// session errors are swallowed (its session is *supposed* to die);
    /// clean workers still propagate errors through
    /// [`LoopbackCluster::join`].
    pub fn spawn_replicated_faulty(
        ds: &Dataset,
        chunk_rows: usize,
        drills: &[WorkerDrill],
    ) -> Result<LoopbackCluster> {
        if drills.is_empty() {
            return Err(Error::Config("loopback: need at least one worker".into()));
        }
        let mut addrs = Vec::with_capacity(drills.len());
        let mut listeners = Vec::with_capacity(drills.len());
        for _ in drills {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            addrs.push(listener.local_addr()?.to_string());
            listeners.push(listener);
        }
        let handles = drills
            .iter()
            .zip(listeners)
            .map(|(&drill, listener)| {
                let full = Dataset::from_vec(ds.rows(0, ds.len()).to_vec(), ds.dim())?;
                let w = ShardWorker::new(Box::new(OwnedMemorySource::new(full)), chunk_rows)?;
                Ok(std::thread::spawn(move || serve_drill(&w, &listener, drill)))
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(LoopbackCluster { addrs, handles })
    }

    /// Wait for every worker thread, propagating the first worker-side
    /// error (a panic becomes [`Error::Worker`]). Call after the leader
    /// finishes; a leader that errored out closed its connections, so
    /// workers observe end-of-session and exit rather than hang.
    pub fn join(self) -> Result<()> {
        let mut first_err: Option<Error> = None;
        for (i, h) in self.handles.into_iter().enumerate() {
            let outcome = match h.join() {
                Ok(outcome) => outcome,
                Err(_) => Err(Error::Worker(format!("loopback worker {i} panicked"))),
            };
            if first_err.is_none() {
                if let Err(e) = outcome {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

/// Serve up to `drill.sessions` sessions on `listener`; the first runs
/// under the drill's fault. Accept waits are deadline-bounded so a
/// leader that never opens a later session (the run finished without
/// needing the rejoin) cannot hang [`LoopbackCluster::join`].
fn serve_drill(w: &ShardWorker, listener: &TcpListener, drill: WorkerDrill) -> Result<()> {
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + Duration::from_secs(20);
    for session in 0..drill.sessions.max(1) {
        let stream = loop {
            match listener.accept() {
                Ok((s, _)) => break Some(s),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    if Instant::now() >= deadline {
                        break None;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e.into()),
            }
        };
        let Some(stream) = stream else {
            return Ok(()); // the leader never needed this session
        };
        stream.set_nonblocking(false)?;
        let fault = if session == 0 { drill.fault } else { SessionFault::default() };
        match w.serve_conn_fault(stream, fault) {
            Ok(()) => {}
            // a drilled session is expected to die mid-frame (e.g. a
            // stalled reply written to a socket the leader timed out
            // and closed) — that is the drill working, not a failure
            Err(_) if drill.is_faulty() => {}
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    #[test]
    fn spawn_validates() {
        assert!(LoopbackCluster::spawn(Vec::new()).is_err());
        let ds = MixtureSpec::paper_2d(4).generate(10, 1);
        assert!(LoopbackCluster::spawn_dataset(&ds, 0, 8).is_err());
    }

    #[test]
    fn addrs_are_distinct_localhost_ports() {
        let ds = MixtureSpec::paper_2d(4).generate(30, 1);
        let c = LoopbackCluster::spawn_dataset(&ds, 3, 8).unwrap();
        assert_eq!(c.addrs.len(), 3);
        let mut uniq = c.addrs.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3);
        assert!(c.addrs.iter().all(|a| a.starts_with("127.0.0.1:")));
        // connect-and-close each so the single-session workers exit
        for a in &c.addrs {
            drop(std::net::TcpStream::connect(a).unwrap());
        }
        c.join().unwrap();
    }

    #[test]
    fn replicated_workers_report_the_full_dataset() {
        use crate::cluster::wire::{self, Frame, WIRE_VERSION};
        let ds = MixtureSpec::paper_2d(4).generate(40, 2);
        let c = LoopbackCluster::spawn_replicated(&ds, 2, 16).unwrap();
        for a in &c.addrs {
            let mut conn = std::net::TcpStream::connect(a).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            match wire::read_frame(&mut conn, "spec").unwrap().0 {
                // every worker owns all 40 rows, not a shard
                Frame::ShardSpec { rows, dim } => {
                    assert_eq!((rows, dim), (40u64, 2u32));
                }
                other => panic!("unexpected {other:?}"),
            }
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        }
        c.join().unwrap();
    }
}
