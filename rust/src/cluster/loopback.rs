//! In-process loopback cluster: spawn shard workers as threads on
//! `127.0.0.1:0` so the full wire protocol — sockets, frames, timeouts
//! — is exercised inside `cargo test` and `cargo bench` with no
//! multi-machine infrastructure (DESIGN.md §10).
//!
//! Each worker thread serves exactly one leader session and exits; the
//! harness is therefore single-shot — spawn, run the leader, [`join`]
//! to propagate worker-side errors. Shards are the contiguous
//! [`shard_ranges`] decomposition, the same one `oocore` and the static
//! threaded engine use, so a loopback `dist(S)` run is comparable
//! bit-for-bit with `threads(p = S)` and `oocore(shards = S)`.
//!
//! [`join`]: LoopbackCluster::join

use std::net::TcpListener;

use crate::cluster::worker::ShardWorker;
use crate::data::dataset::shard_ranges;
use crate::data::source::OwnedMemorySource;
use crate::data::Dataset;
use crate::error::{Error, Result};

/// Handle to a set of loopback worker threads.
pub struct LoopbackCluster {
    /// Worker addresses in ascending shard order — pass to
    /// [`crate::kmeans::dist::run`] as-is.
    pub addrs: Vec<String>,
    handles: Vec<std::thread::JoinHandle<Result<()>>>,
}

impl LoopbackCluster {
    /// Bind each worker to an ephemeral localhost port and serve one
    /// leader session on its own thread. `workers[i]` is shard `i`.
    pub fn spawn(workers: Vec<ShardWorker>) -> Result<LoopbackCluster> {
        if workers.is_empty() {
            return Err(Error::Config("loopback: need at least one worker".into()));
        }
        // bind every listener BEFORE spawning any thread: addresses are
        // known up front, the leader cannot race a listener into
        // existence, and a bind failure (port exhaustion) errors out
        // cleanly instead of leaking already-spawned accept() threads
        let mut addrs = Vec::with_capacity(workers.len());
        let mut listeners = Vec::with_capacity(workers.len());
        for _ in &workers {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            addrs.push(listener.local_addr()?.to_string());
            listeners.push(listener);
        }
        let handles = workers
            .into_iter()
            .zip(listeners)
            .map(|(w, listener)| std::thread::spawn(move || w.serve_listener(&listener, true)))
            .collect();
        Ok(LoopbackCluster { addrs, handles })
    }

    /// Spawn `shards` workers over contiguous [`shard_ranges`] slices
    /// of `ds` (each worker owns a copy of its rows — process-boundary
    /// semantics, even in-process). `chunk_rows` never affects results.
    pub fn spawn_dataset(
        ds: &Dataset,
        shards: usize,
        chunk_rows: usize,
    ) -> Result<LoopbackCluster> {
        if shards == 0 {
            return Err(Error::Config("loopback: shards must be >= 1".into()));
        }
        let mut workers = Vec::with_capacity(shards);
        for (lo, hi) in shard_ranges(ds.len(), shards) {
            let shard = Dataset::from_vec(ds.rows(lo, hi).to_vec(), ds.dim())?;
            workers.push(ShardWorker::new(Box::new(OwnedMemorySource::new(shard)), chunk_rows)?);
        }
        LoopbackCluster::spawn(workers)
    }

    /// Wait for every worker thread, propagating the first worker-side
    /// error (a panic becomes [`Error::Worker`]). Call after the leader
    /// finishes; a leader that errored out closed its connections, so
    /// workers observe end-of-session and exit rather than hang.
    pub fn join(self) -> Result<()> {
        let mut first_err: Option<Error> = None;
        for (i, h) in self.handles.into_iter().enumerate() {
            let outcome = match h.join() {
                Ok(outcome) => outcome,
                Err(_) => Err(Error::Worker(format!("loopback worker {i} panicked"))),
            };
            if first_err.is_none() {
                if let Err(e) = outcome {
                    first_err = Some(e);
                }
            }
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;

    #[test]
    fn spawn_validates() {
        assert!(LoopbackCluster::spawn(Vec::new()).is_err());
        let ds = MixtureSpec::paper_2d(4).generate(10, 1);
        assert!(LoopbackCluster::spawn_dataset(&ds, 0, 8).is_err());
    }

    #[test]
    fn addrs_are_distinct_localhost_ports() {
        let ds = MixtureSpec::paper_2d(4).generate(30, 1);
        let c = LoopbackCluster::spawn_dataset(&ds, 3, 8).unwrap();
        assert_eq!(c.addrs.len(), 3);
        let mut uniq = c.addrs.clone();
        uniq.sort();
        uniq.dedup();
        assert_eq!(uniq.len(), 3);
        assert!(c.addrs.iter().all(|a| a.starts_with("127.0.0.1:")));
        // connect-and-close each so the single-session workers exit
        for a in &c.addrs {
            drop(std::net::TcpStream::connect(a).unwrap());
        }
        c.join().unwrap();
    }
}
