//! The shard worker: one process (or loopback thread) that owns one
//! data shard and answers a leader's frames (DESIGN.md §10).
//!
//! A worker wraps any [`DataSource`] — resident memory, a streamed
//! `.pkd` file, or a seeded GMM generator — optionally restricted to a
//! row range (`parakm worker --shard i/S` points every worker at the
//! same file and gives each its [`shard_ranges`] slice). Per `Assign`
//! frame it replays the exact out-of-core shard fold
//! ([`crate::kmeans::streaming`]'s `stream_shard`): chunks in ascending
//! row order through the continuing f64 accumulator. The worker's
//! partials are therefore bit-identical to the thread the `oocore`
//! engine would have run over the same rows — chunk size, kernel tier
//! and even a mixed-tier cluster (every tier is bit-identical by the
//! kernel contract) cannot perturb them. That tier clause holds for
//! the default `exact` distance policy; an `Assign` carrying the `dot`
//! policy (DESIGN.md §11) computes norm-trick FMA distances — still
//! chunk-size-independent, with the shard's `‖x‖²` cache built once
//! per session — but mixed-tier clusters may then differ in last-ulp
//! SSE.
//!
//! A session serves exactly one leader: `Hello` (or `Rejoin`, the
//! elastic leader's reconnect — same handshake, distinguishable in
//! logs) through `Shutdown`, or the leader closing the connection —
//! workers treat a close at a frame *boundary* as the end of the
//! session whether it arrives as EOF or as a reset, so a dying leader
//! never wedges a worker and never pollutes its log with spurious
//! errors. Requests the worker cannot satisfy (dimension mismatch,
//! out-of-range gather, chunk dispatch at a sharded worker) are
//! answered with `ErrMsg` frames — the leader fails fast; the worker
//! keeps serving.
//!
//! ## Chunk-capable serving (elastic, DESIGN.md §12)
//!
//! A `ChunkAssign` frame asks for the zero-seeded partial statistics of
//! one chunk of the global [`crate::kmeans::sched`] grid. Because the
//! elastic leader may hand *any* chunk to *any* worker (and the same
//! chunk to several), chunk dispatch requires a **full-view** worker —
//! one whose shard is the entire source (replicated `.pkd` file or
//! identical `--synthetic` spec, no `--shard`). A sharded worker
//! answers `ErrMsg` so a misconfigured cluster fails typed instead of
//! silently clustering the wrong rows.

use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

use crate::cluster::wire::{self, Frame, PhaseNs, MIN_WIRE_VERSION, WIRE_VERSION};
use crate::data::dataset::shard_ranges;
use crate::data::source::DataSource;
use crate::error::{ClusterError, Error, Result};
use crate::kmeans::sched;
use crate::kmeans::step::PartialStats;
use crate::kmeans::streaming::{shard_norms, stream_shard};
use crate::linalg::kernel;
use crate::linalg::kernel::DistancePolicy;

/// Scripted misbehavior for failure drills (integration tests and the
/// OPERATIONS.md walkthroughs): makes a real chunk-serving worker
/// crash or stall at a deterministic point in its session. The default
/// value injects nothing.
#[derive(Debug, Clone, Copy, Default)]
pub struct SessionFault {
    /// Drop the connection (simulated crash) once this many
    /// `ChunkAssign` frames have been answered — the next one goes
    /// unanswered.
    pub die_after_chunks: Option<u64>,
    /// After answering this many `ChunkAssign` frames, sleep this long
    /// before every subsequent reply (simulated stall/straggler).
    pub stall_after_chunks: Option<(u64, Duration)>,
}

/// A leader-facing server over one shard of rows.
pub struct ShardWorker {
    source: Box<dyn DataSource + Send + Sync>,
    /// Global row range this worker owns within `source`.
    lo: usize,
    hi: usize,
    /// Rows per streamed chunk (never affects results — the
    /// chunked-accumulation contract).
    chunk_rows: usize,
}

impl ShardWorker {
    /// A worker owning the whole source.
    pub fn new(
        source: Box<dyn DataSource + Send + Sync>,
        chunk_rows: usize,
    ) -> Result<ShardWorker> {
        let hi = source.len();
        ShardWorker::with_range(source, 0, hi, chunk_rows)
    }

    /// A worker owning rows `[lo, hi)` of `source` — how S workers
    /// share one `.pkd` file (`--shard i/S`).
    pub fn with_range(
        source: Box<dyn DataSource + Send + Sync>,
        lo: usize,
        hi: usize,
        chunk_rows: usize,
    ) -> Result<ShardWorker> {
        if chunk_rows == 0 {
            return Err(Error::Config("worker: chunk_rows must be >= 1".into()));
        }
        if lo > hi || hi > source.len() {
            return Err(Error::Config(format!(
                "worker: shard range [{lo}, {hi}) out of bounds for n = {}",
                source.len()
            )));
        }
        if source.dim() == 0 {
            return Err(Error::Shape("worker: source dim must be >= 1".into()));
        }
        // resolve the hot-path tier up front so a bad PARAKM_KERNEL
        // aborts at worker start, not mid-session
        let _ = kernel::active_tier();
        Ok(ShardWorker { source, lo, hi, chunk_rows })
    }

    /// Rows this worker owns.
    pub fn rows(&self) -> usize {
        self.hi - self.lo
    }

    /// Shard slice of `source` a `--shard idx/total` worker owns.
    pub fn shard_slice(n: usize, idx: usize, total: usize) -> Result<(usize, usize)> {
        if total == 0 || idx >= total {
            return Err(Error::Config(format!(
                "worker: shard {idx}/{total} is not a valid slice (want idx < total >= 1)"
            )));
        }
        Ok(shard_ranges(n, total)[idx])
    }

    /// One-line description for logs.
    pub fn describe(&self) -> String {
        format!(
            "{} rows [{}, {}) of {} ({}D, chunk {})",
            self.rows(),
            self.lo,
            self.hi,
            self.source.describe(),
            self.source.dim(),
            self.chunk_rows
        )
    }

    /// Accept-and-serve loop over `listener`: one leader session at a
    /// time; `once` stops after the first session (loopback harness,
    /// CI smoke). Per-session errors are logged and the loop continues
    /// — a misbehaving leader (or a transient accept failure such as
    /// ECONNABORTED from a connection reset mid-accept) must not kill
    /// a long-running worker.
    pub fn serve_listener(&self, listener: &TcpListener, once: bool) -> Result<()> {
        loop {
            let (stream, peer) = match listener.accept() {
                Ok(conn) => conn,
                Err(e) if once => return Err(e.into()),
                Err(e) => {
                    eprintln!("worker: accept failed: {e}");
                    continue;
                }
            };
            let outcome = self.serve_conn(stream);
            match &outcome {
                Ok(()) => eprintln!("worker: session with {peer} ended"),
                Err(e) => eprintln!("worker: session with {peer} failed: {e}"),
            }
            if once {
                return outcome;
            }
        }
    }

    /// Serve one leader session on an accepted connection until
    /// `Shutdown` or a clean close. Frame/IO corruption from the leader
    /// is a typed error (the session dies, the worker may accept the
    /// next).
    pub fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        self.serve_conn_fault(stream, SessionFault::default())
    }

    /// [`ShardWorker::serve_conn`] with scripted misbehavior — the
    /// failure-drill entry point ([`SessionFault`]). A session that
    /// *dies on script* returns `Ok(())`: from the worker's point of
    /// view the drill ran to plan; only genuine frame/IO corruption is
    /// an error.
    pub fn serve_conn_fault(&self, stream: TcpStream, fault: SessionFault) -> Result<()> {
        // small frames dominate the conversation: Nagle + delayed ACK
        // would add ~40 ms stalls per iteration round trip
        let _ = stream.set_nodelay(true);
        let mut stream = stream;
        let n = self.rows();
        let d = self.source.dim();
        let mut assign = vec![-1i32; n];
        let mut stats: Option<PartialStats> = None;
        // per-shard `‖x‖²` cache for the dot policy: one bounded-memory
        // pass on the first dot Assign (or ChunkAssign) of the session,
        // then every iteration reuses it (the shard's bytes are fixed).
        // Chunk dispatch requires the full view, so the same cache
        // serves both request kinds.
        let mut norm_cache: Option<Vec<f32>> = None;
        // chunk frames answered so far — drives the fault script
        let mut chunks_served = 0u64;
        // negotiated session version: phase timings piggyback on
        // replies only when the leader also speaks v4 (a v3 leader's
        // decoder would reject the trailing block as payload garbage)
        let mut peer_version: u16 = WIRE_VERSION;

        loop {
            let frame = match wire::read_frame_opt(&mut stream)? {
                Some((f, _)) => f,
                None => return Ok(()), // leader closed at a frame boundary
            };
            match frame {
                Frame::Hello { version } | Frame::Rejoin { version } => {
                    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&version) {
                        let msg = format!(
                            "protocol version mismatch: leader {version}, worker \
                             speaks {MIN_WIRE_VERSION}..={WIRE_VERSION}"
                        );
                        wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg.clone() })?;
                        return Err(Error::Cluster(ClusterError::Protocol(msg)));
                    }
                    peer_version = version;
                    wire::write_frame(
                        &mut stream,
                        &Frame::ShardSpec { rows: n as u64, dim: d as u32 },
                    )?;
                }
                Frame::Assign { k, dim, policy, centroids } => {
                    if dim as usize != d {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!("shard is {d}D, leader sent {dim}D centroids"),
                            },
                        )?;
                        continue;
                    }
                    if k == 0 || centroids.len() != (k as usize) * d {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!(
                                    "bad Assign shape: k {k}, dim {dim}, {} centroid values",
                                    centroids.len()
                                ),
                            },
                        )?;
                        continue;
                    }
                    let k = k as usize;
                    // reuse the stats buffer across iterations; realloc
                    // only if the leader changes k mid-session
                    let stats = match &mut stats {
                        Some(s) if s.k == k && s.dim == d => {
                            s.reset();
                            s
                        }
                        slot => slot.insert(PartialStats::zeros(k, d)),
                    };
                    if policy == DistancePolicy::Dot && norm_cache.is_none() {
                        match shard_norms(
                            self.source.as_ref(),
                            self.lo,
                            self.hi,
                            self.chunk_rows,
                            d,
                        ) {
                            Ok(norms) => norm_cache = Some(norms),
                            Err(e) => {
                                let msg = format!("shard norm pass failed: {e}");
                                let _ = wire::write_frame(
                                    &mut stream,
                                    &Frame::ErrMsg { message: msg },
                                );
                                return Err(e);
                            }
                        }
                    }
                    let x_norms = match policy {
                        DistancePolicy::Dot => norm_cache.as_deref(),
                        DistancePolicy::Exact => None,
                    };
                    let t_assign = Instant::now();
                    if let Err(e) = stream_shard(
                        self.source.as_ref(),
                        self.lo,
                        self.hi,
                        self.chunk_rows,
                        d,
                        &centroids,
                        k,
                        &mut assign,
                        stats,
                        policy,
                        x_norms,
                    ) {
                        // tell the leader why before the session dies,
                        // so its error names the worker-side cause
                        let msg = format!("shard read failed: {e}");
                        let _ = wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg });
                        return Err(e);
                    }
                    let assign_ns = t_assign.elapsed().as_nanos() as u64;
                    let t_ser = Instant::now();
                    let counts = stats.counts.clone();
                    let sums = stats.sums.clone();
                    let phase = (peer_version >= 4).then(|| PhaseNs {
                        assign_ns,
                        ser_ns: t_ser.elapsed().as_nanos() as u64,
                    });
                    wire::write_frame(
                        &mut stream,
                        &Frame::Partials {
                            k: k as u32,
                            dim: d as u32,
                            counts,
                            sums,
                            sse: stats.sse,
                            phase,
                        },
                    )?;
                }
                Frame::ChunkAssign { chunk, lo, hi, k, dim, policy, want_assign, centroids } => {
                    // fault script: a scripted crash drops the
                    // connection instead of answering — the leader sees
                    // a vanished worker, exactly like a killed process
                    if let Some(m) = fault.die_after_chunks {
                        if chunks_served >= m {
                            return Ok(());
                        }
                    }
                    if let Some((m, pause)) = fault.stall_after_chunks {
                        if chunks_served >= m {
                            std::thread::sleep(pause);
                        }
                    }
                    // chunk dispatch presumes the leader's global row
                    // space IS this worker's row space
                    if self.lo != 0 || self.hi != self.source.len() {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!(
                                    "elastic chunk dispatch requires a full-view worker; \
                                     this one owns rows [{}, {}) of {} (drop --shard and \
                                     replicate the input)",
                                    self.lo,
                                    self.hi,
                                    self.source.len()
                                ),
                            },
                        )?;
                        continue;
                    }
                    if dim as usize != d {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!("shard is {d}D, leader sent {dim}D centroids"),
                            },
                        )?;
                        continue;
                    }
                    if k == 0 || centroids.len() != (k as usize) * d {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!(
                                    "bad ChunkAssign shape: k {k}, dim {dim}, {} centroid values",
                                    centroids.len()
                                ),
                            },
                        )?;
                        continue;
                    }
                    // both sides must agree on the deterministic chunk
                    // grid — it is what keys the partials fold
                    let (clo, chi) = sched::chunk_range(chunk as usize, n);
                    if chunk as usize >= sched::chunk_count(n)
                        || lo != clo as u64
                        || hi != chi as u64
                    {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!(
                                    "chunk grid mismatch: leader sent chunk {chunk} = \
                                     [{lo}, {hi}), worker grid has [{clo}, {chi}) for n = {n}"
                                ),
                            },
                        )?;
                        continue;
                    }
                    let k = k as usize;
                    let stats = match &mut stats {
                        Some(s) if s.k == k && s.dim == d => {
                            s.reset(); // chunk partials are zero-seeded
                            s
                        }
                        slot => slot.insert(PartialStats::zeros(k, d)),
                    };
                    if policy == DistancePolicy::Dot && norm_cache.is_none() {
                        match shard_norms(self.source.as_ref(), 0, n, self.chunk_rows, d) {
                            Ok(norms) => norm_cache = Some(norms),
                            Err(e) => {
                                let msg = format!("shard norm pass failed: {e}");
                                let _ = wire::write_frame(
                                    &mut stream,
                                    &Frame::ErrMsg { message: msg },
                                );
                                return Err(e);
                            }
                        }
                    }
                    let x_norms = match policy {
                        DistancePolicy::Dot => norm_cache.as_deref().map(|c| &c[clo..chi]),
                        DistancePolicy::Exact => None,
                    };
                    let t_assign = Instant::now();
                    if let Err(e) = stream_shard(
                        self.source.as_ref(),
                        clo,
                        chi,
                        self.chunk_rows,
                        d,
                        &centroids,
                        k,
                        &mut assign[clo..chi],
                        stats,
                        policy,
                        x_norms,
                    ) {
                        let msg = format!("chunk {chunk} read failed: {e}");
                        let _ = wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg });
                        return Err(e);
                    }
                    let assign_ns = t_assign.elapsed().as_nanos() as u64;
                    chunks_served += 1;
                    let t_ser = Instant::now();
                    let counts = stats.counts.clone();
                    let sums = stats.sums.clone();
                    let chunk_assign =
                        if want_assign { assign[clo..chi].to_vec() } else { Vec::new() };
                    let phase = (peer_version >= 4).then(|| PhaseNs {
                        assign_ns,
                        ser_ns: t_ser.elapsed().as_nanos() as u64,
                    });
                    wire::write_frame(
                        &mut stream,
                        &Frame::ChunkPartials {
                            chunk,
                            k: k as u32,
                            dim: d as u32,
                            counts,
                            sums,
                            sse: stats.sse,
                            assign: chunk_assign,
                            phase,
                        },
                    )?;
                }
                Frame::Gather { indices } => {
                    if let Some(&bad) = indices.iter().find(|&&i| i >= n as u64) {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!("gather: row {bad} out of range (shard n = {n})"),
                            },
                        )?;
                        continue;
                    }
                    // shard-local → source-global row indices
                    let global: Vec<usize> =
                        indices.iter().map(|&i| self.lo + i as usize).collect();
                    let rows = self.source.gather(&global)?;
                    wire::write_frame(&mut stream, &Frame::Rows { dim: d as u32, rows })?;
                }
                Frame::FetchAssign => {
                    wire::write_frame(&mut stream, &Frame::AssignShard { assign: assign.clone() })?;
                }
                Frame::Shutdown => return Ok(()),
                other => {
                    let msg = format!("unexpected {} frame from the leader", other.name());
                    wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg.clone() })?;
                    return Err(Error::Cluster(ClusterError::Protocol(msg)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::OwnedMemorySource;
    use crate::data::MixtureSpec;

    fn worker(n: usize) -> ShardWorker {
        let ds = MixtureSpec::paper_2d(4).generate(n, 3);
        ShardWorker::new(Box::new(OwnedMemorySource::new(ds)), 64).unwrap()
    }

    #[test]
    fn construction_validates() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 3);
        let src = || Box::new(OwnedMemorySource::new(ds.clone()));
        assert!(ShardWorker::new(src(), 0).is_err()); // zero chunk
        assert!(ShardWorker::with_range(src(), 50, 40, 8).is_err()); // inverted
        assert!(ShardWorker::with_range(src(), 0, 101, 8).is_err()); // past n
        let w = ShardWorker::with_range(src(), 25, 75, 8).unwrap();
        assert_eq!(w.rows(), 50);
        assert!(w.describe().contains("[25, 75)"), "{}", w.describe());
    }

    #[test]
    fn shard_slice_matches_shard_ranges() {
        assert_eq!(ShardWorker::shard_slice(10, 0, 3).unwrap(), (0, 4));
        assert_eq!(ShardWorker::shard_slice(10, 1, 3).unwrap(), (4, 7));
        assert_eq!(ShardWorker::shard_slice(10, 2, 3).unwrap(), (7, 10));
        assert!(ShardWorker::shard_slice(10, 3, 3).is_err());
        assert!(ShardWorker::shard_slice(10, 0, 0).is_err());
    }

    /// Drive one session over a real localhost socket pair — the
    /// protocol exercised without the leader engine.
    #[test]
    fn session_answers_every_frame_kind() {
        let w = worker(100);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();

            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let spec = wire::read_frame(&mut conn, "spec").unwrap().0;
            assert_eq!(spec, Frame::ShardSpec { rows: 100, dim: 2 });

            wire::write_frame(&mut conn, &Frame::Gather { indices: vec![5, 0, 99] }).unwrap();
            match wire::read_frame(&mut conn, "rows").unwrap().0 {
                Frame::Rows { dim: 2, rows } => assert_eq!(rows.len(), 6),
                other => panic!("unexpected {other:?}"),
            }

            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 2,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0, 0.0, 10.0, 10.0],
                },
            )
            .unwrap();
            let exact_partials = match wire::read_frame(&mut conn, "partials").unwrap().0 {
                Frame::Partials { k: 2, dim: 2, counts, sums, sse, phase } => {
                    assert_eq!(counts.iter().sum::<u64>(), 100);
                    assert_eq!(sums.len(), 4);
                    // a v4 session always carries the timing block
                    assert!(phase.is_some(), "v4 session must piggyback phase timings");
                    (counts, sums, sse)
                }
                other => panic!("unexpected {other:?}"),
            };

            // a dot-policy Assign on the same session: the full
            // partition still comes back, with SSE tolerance-close to
            // the exact pass (a razor-edge point may pick the other of
            // two near-equidistant centroids, so counts are not byte-
            // compared here — integration_distance.rs pins the strong
            // contract on the converged paper suites)
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 2,
                    dim: 2,
                    policy: DistancePolicy::Dot,
                    centroids: vec![0.0, 0.0, 10.0, 10.0],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "dot partials").unwrap().0 {
                Frame::Partials { k: 2, dim: 2, counts, sums, sse, .. } => {
                    assert_eq!(counts.iter().sum::<u64>(), 100);
                    assert_eq!(sums.len(), 4);
                    let rel = (sse - exact_partials.2).abs() / exact_partials.2.max(1.0);
                    assert!(rel < 1e-3, "dot sse {sse} vs exact {}", exact_partials.2);
                }
                other => panic!("unexpected {other:?}"),
            }

            wire::write_frame(&mut conn, &Frame::FetchAssign).unwrap();
            match wire::read_frame(&mut conn, "assign").unwrap().0 {
                Frame::AssignShard { assign } => {
                    assert_eq!(assign.len(), 100);
                    assert!(assign.iter().all(|&a| a == 0 || a == 1));
                }
                other => panic!("unexpected {other:?}"),
            }

            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn v3_leader_interoperates_without_phase_block() {
        // a MIN_WIRE_VERSION leader passes the handshake and gets
        // byte-identical v3 replies: no trailing phase block
        let w = worker(100);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: MIN_WIRE_VERSION }).unwrap();
            let spec = wire::read_frame(&mut conn, "spec").unwrap().0;
            assert_eq!(spec, Frame::ShardSpec { rows: 100, dim: 2 });
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0, 0.0],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "partials").unwrap().0 {
                Frame::Partials { phase, .. } => {
                    assert!(phase.is_none(), "v3 session must not carry phase timings")
                }
                other => panic!("unexpected {other:?}"),
            }
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn out_of_range_version_fails_the_handshake_typed() {
        let w = worker(10);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: MIN_WIRE_VERSION - 1 })
                .unwrap();
            match wire::read_frame(&mut conn, "err").unwrap().0 {
                Frame::ErrMsg { message } => {
                    assert!(message.contains("version mismatch"), "{message}")
                }
                other => panic!("unexpected {other:?}"),
            }
        });
        assert!(w.serve_listener(&listener, true).is_err());
        handle.join().unwrap();
    }

    #[test]
    fn dim_mismatch_gets_errmsg_session_survives() {
        let w = worker(50);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            // 3D centroids at a 2D shard
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 1,
                    dim: 3,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0; 3],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "err").unwrap().0 {
                Frame::ErrMsg { message } => assert!(message.contains("2D"), "{message}"),
                other => panic!("unexpected {other:?}"),
            }
            // the session is still alive: a correct Assign now works
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0; 2],
                },
            )
            .unwrap();
            assert!(matches!(
                wire::read_frame(&mut conn, "partials").unwrap().0,
                Frame::Partials { .. }
            ));
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn leader_disconnect_ends_session_cleanly() {
        let w = worker(20);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            // drop without Shutdown — a dying leader
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn leader_reset_between_frames_ends_session_cleanly() {
        // the frame-boundary rule, RST flavor: the leader dies with the
        // worker's last reply still unread in its receive buffer, so
        // its close sends RST (not FIN). The worker's next header read
        // fails with ECONNRESET at offset 0 — a clean session end, not
        // a logged error.
        let w = worker(2048);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Rejoin { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            wire::write_frame(
                &mut conn,
                &Frame::ChunkAssign {
                    chunk: 0,
                    lo: 0,
                    hi: sched::chunk_range(0, 2048).1 as u64,
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    want_assign: false,
                    centroids: vec![0.0, 0.0],
                },
            )
            .unwrap();
            // give the worker time to land its reply in our receive
            // buffer, then vanish without reading it
            std::thread::sleep(Duration::from_millis(300));
            drop(conn);
        });
        // Ok either way the close manifests (EOF or RST) — the pin is
        // that neither surfaces as a session error
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn chunk_session_serves_the_grid() {
        // a full-view worker answers the whole chunk grid: ids echo
        // back, counts cover every row exactly once, want_assign
        // returns the chunk's labels
        let n = 2500usize;
        let w = worker(n);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            let mut total = 0u64;
            for ci in 0..sched::chunk_count(n) {
                let (lo, hi) = sched::chunk_range(ci, n);
                wire::write_frame(
                    &mut conn,
                    &Frame::ChunkAssign {
                        chunk: ci as u64,
                        lo: lo as u64,
                        hi: hi as u64,
                        k: 2,
                        dim: 2,
                        policy: DistancePolicy::Exact,
                        want_assign: true,
                        centroids: vec![0.0, 0.0, 10.0, 10.0],
                    },
                )
                .unwrap();
                match wire::read_frame(&mut conn, "chunk partials").unwrap().0 {
                    Frame::ChunkPartials { chunk, k: 2, dim: 2, counts, sums, assign, .. } => {
                        assert_eq!(chunk, ci as u64);
                        assert_eq!(sums.len(), 4);
                        assert_eq!(assign.len(), hi - lo);
                        assert!(assign.iter().all(|&a| a == 0 || a == 1));
                        total += counts.iter().sum::<u64>();
                    }
                    other => panic!("unexpected {other:?}"),
                }
            }
            assert_eq!(total, n as u64, "chunks partition the rows");
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn sharded_worker_rejects_chunk_dispatch_typed() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 3);
        let w =
            ShardWorker::with_range(Box::new(OwnedMemorySource::new(ds)), 0, 50, 64).unwrap();
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            wire::write_frame(
                &mut conn,
                &Frame::ChunkAssign {
                    chunk: 0,
                    lo: 0,
                    hi: 50,
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    want_assign: false,
                    centroids: vec![0.0, 0.0],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "err").unwrap().0 {
                Frame::ErrMsg { message } => {
                    assert!(message.contains("full-view"), "{message}")
                }
                other => panic!("unexpected {other:?}"),
            }
            // grid mismatch on a full-range request is also typed: the
            // session survives both refusals
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn chunk_grid_mismatch_is_typed_and_survivable() {
        let n = 2000usize;
        let w = worker(n);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            // chunk 0 with the wrong row range
            wire::write_frame(
                &mut conn,
                &Frame::ChunkAssign {
                    chunk: 0,
                    lo: 0,
                    hi: 17,
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    want_assign: false,
                    centroids: vec![0.0, 0.0],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "err").unwrap().0 {
                Frame::ErrMsg { message } => {
                    assert!(message.contains("chunk grid mismatch"), "{message}")
                }
                other => panic!("unexpected {other:?}"),
            }
            // a correct request on the same session still works
            let (lo, hi) = sched::chunk_range(0, n);
            wire::write_frame(
                &mut conn,
                &Frame::ChunkAssign {
                    chunk: 0,
                    lo: lo as u64,
                    hi: hi as u64,
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    want_assign: false,
                    centroids: vec![0.0, 0.0],
                },
            )
            .unwrap();
            assert!(matches!(
                wire::read_frame(&mut conn, "partials").unwrap().0,
                Frame::ChunkPartials { .. }
            ));
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn scripted_crash_drops_the_session_ok() {
        // die_after_chunks = 1: the first chunk answers, the second
        // vanishes; the worker reports the drill as a clean session
        let n = 2048usize;
        let w = worker(n);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            for ci in 0..2u64 {
                let (lo, hi) = sched::chunk_range(ci as usize, n);
                wire::write_frame(
                    &mut conn,
                    &Frame::ChunkAssign {
                        chunk: ci,
                        lo: lo as u64,
                        hi: hi as u64,
                        k: 1,
                        dim: 2,
                        policy: DistancePolicy::Exact,
                        want_assign: false,
                        centroids: vec![0.0, 0.0],
                    },
                )
                .unwrap();
                if ci == 0 {
                    assert!(matches!(
                        wire::read_frame(&mut conn, "partials").unwrap().0,
                        Frame::ChunkPartials { .. }
                    ));
                } else {
                    // the scripted crash: no reply, connection gone
                    assert!(wire::read_frame(&mut conn, "partials").is_err());
                }
            }
        });
        let (stream, _) = listener.accept().unwrap();
        w.serve_conn_fault(
            stream,
            SessionFault { die_after_chunks: Some(1), ..Default::default() },
        )
        .unwrap();
        handle.join().unwrap();
    }
}
