//! The shard worker: one process (or loopback thread) that owns one
//! data shard and answers a leader's frames (DESIGN.md §10).
//!
//! A worker wraps any [`DataSource`] — resident memory, a streamed
//! `.pkd` file, or a seeded GMM generator — optionally restricted to a
//! row range (`parakm worker --shard i/S` points every worker at the
//! same file and gives each its [`shard_ranges`] slice). Per `Assign`
//! frame it replays the exact out-of-core shard fold
//! ([`crate::kmeans::streaming`]'s `stream_shard`): chunks in ascending
//! row order through the continuing f64 accumulator. The worker's
//! partials are therefore bit-identical to the thread the `oocore`
//! engine would have run over the same rows — chunk size, kernel tier
//! and even a mixed-tier cluster (every tier is bit-identical by the
//! kernel contract) cannot perturb them. That tier clause holds for
//! the default `exact` distance policy; an `Assign` carrying the `dot`
//! policy (DESIGN.md §11) computes norm-trick FMA distances — still
//! chunk-size-independent, with the shard's `‖x‖²` cache built once
//! per session — but mixed-tier clusters may then differ in last-ulp
//! SSE.
//!
//! A session serves exactly one leader: `Hello` through `Shutdown` (or
//! the leader closing the connection — workers treat a close at a frame
//! boundary as the end of the session, so a dying leader never wedges a
//! worker). Requests the worker cannot satisfy (dimension mismatch,
//! out-of-range gather) are answered with `ErrMsg` frames — the leader
//! fails fast; the worker keeps serving.

use std::net::{TcpListener, TcpStream};

use crate::cluster::wire::{self, Frame, WIRE_VERSION};
use crate::data::dataset::shard_ranges;
use crate::data::source::DataSource;
use crate::error::{ClusterError, Error, Result};
use crate::kmeans::step::PartialStats;
use crate::kmeans::streaming::{shard_norms, stream_shard};
use crate::linalg::kernel;
use crate::linalg::kernel::DistancePolicy;

/// A leader-facing server over one shard of rows.
pub struct ShardWorker {
    source: Box<dyn DataSource + Send + Sync>,
    /// Global row range this worker owns within `source`.
    lo: usize,
    hi: usize,
    /// Rows per streamed chunk (never affects results — the
    /// chunked-accumulation contract).
    chunk_rows: usize,
}

impl ShardWorker {
    /// A worker owning the whole source.
    pub fn new(
        source: Box<dyn DataSource + Send + Sync>,
        chunk_rows: usize,
    ) -> Result<ShardWorker> {
        let hi = source.len();
        ShardWorker::with_range(source, 0, hi, chunk_rows)
    }

    /// A worker owning rows `[lo, hi)` of `source` — how S workers
    /// share one `.pkd` file (`--shard i/S`).
    pub fn with_range(
        source: Box<dyn DataSource + Send + Sync>,
        lo: usize,
        hi: usize,
        chunk_rows: usize,
    ) -> Result<ShardWorker> {
        if chunk_rows == 0 {
            return Err(Error::Config("worker: chunk_rows must be >= 1".into()));
        }
        if lo > hi || hi > source.len() {
            return Err(Error::Config(format!(
                "worker: shard range [{lo}, {hi}) out of bounds for n = {}",
                source.len()
            )));
        }
        if source.dim() == 0 {
            return Err(Error::Shape("worker: source dim must be >= 1".into()));
        }
        // resolve the hot-path tier up front so a bad PARAKM_KERNEL
        // aborts at worker start, not mid-session
        let _ = kernel::active_tier();
        Ok(ShardWorker { source, lo, hi, chunk_rows })
    }

    /// Rows this worker owns.
    pub fn rows(&self) -> usize {
        self.hi - self.lo
    }

    /// Shard slice of `source` a `--shard idx/total` worker owns.
    pub fn shard_slice(n: usize, idx: usize, total: usize) -> Result<(usize, usize)> {
        if total == 0 || idx >= total {
            return Err(Error::Config(format!(
                "worker: shard {idx}/{total} is not a valid slice (want idx < total >= 1)"
            )));
        }
        Ok(shard_ranges(n, total)[idx])
    }

    /// One-line description for logs.
    pub fn describe(&self) -> String {
        format!(
            "{} rows [{}, {}) of {} ({}D, chunk {})",
            self.rows(),
            self.lo,
            self.hi,
            self.source.describe(),
            self.source.dim(),
            self.chunk_rows
        )
    }

    /// Accept-and-serve loop over `listener`: one leader session at a
    /// time; `once` stops after the first session (loopback harness,
    /// CI smoke). Per-session errors are logged and the loop continues
    /// — a misbehaving leader (or a transient accept failure such as
    /// ECONNABORTED from a connection reset mid-accept) must not kill
    /// a long-running worker.
    pub fn serve_listener(&self, listener: &TcpListener, once: bool) -> Result<()> {
        loop {
            let (stream, peer) = match listener.accept() {
                Ok(conn) => conn,
                Err(e) if once => return Err(e.into()),
                Err(e) => {
                    eprintln!("worker: accept failed: {e}");
                    continue;
                }
            };
            let outcome = self.serve_conn(stream);
            match &outcome {
                Ok(()) => eprintln!("worker: session with {peer} ended"),
                Err(e) => eprintln!("worker: session with {peer} failed: {e}"),
            }
            if once {
                return outcome;
            }
        }
    }

    /// Serve one leader session on an accepted connection until
    /// `Shutdown` or a clean close. Frame/IO corruption from the leader
    /// is a typed error (the session dies, the worker may accept the
    /// next).
    pub fn serve_conn(&self, stream: TcpStream) -> Result<()> {
        // small frames dominate the conversation: Nagle + delayed ACK
        // would add ~40 ms stalls per iteration round trip
        let _ = stream.set_nodelay(true);
        let mut stream = stream;
        let n = self.rows();
        let d = self.source.dim();
        let mut assign = vec![-1i32; n];
        let mut stats: Option<PartialStats> = None;
        // per-shard `‖x‖²` cache for the dot policy: one bounded-memory
        // pass on the first dot Assign of the session, then every
        // iteration reuses it (the shard's bytes are fixed)
        let mut norm_cache: Option<Vec<f32>> = None;

        loop {
            let frame = match wire::read_frame_opt(&mut stream)? {
                Some((f, _)) => f,
                None => return Ok(()), // leader closed at a frame boundary
            };
            match frame {
                Frame::Hello { version } => {
                    if version != WIRE_VERSION {
                        let msg = format!(
                            "protocol version mismatch: leader {version}, worker {WIRE_VERSION}"
                        );
                        wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg.clone() })?;
                        return Err(Error::Cluster(ClusterError::Protocol(msg)));
                    }
                    wire::write_frame(
                        &mut stream,
                        &Frame::ShardSpec { rows: n as u64, dim: d as u32 },
                    )?;
                }
                Frame::Assign { k, dim, policy, centroids } => {
                    if dim as usize != d {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!("shard is {d}D, leader sent {dim}D centroids"),
                            },
                        )?;
                        continue;
                    }
                    if k == 0 || centroids.len() != (k as usize) * d {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!(
                                    "bad Assign shape: k {k}, dim {dim}, {} centroid values",
                                    centroids.len()
                                ),
                            },
                        )?;
                        continue;
                    }
                    let k = k as usize;
                    // reuse the stats buffer across iterations; realloc
                    // only if the leader changes k mid-session
                    let stats = match &mut stats {
                        Some(s) if s.k == k && s.dim == d => {
                            s.reset();
                            s
                        }
                        slot => slot.insert(PartialStats::zeros(k, d)),
                    };
                    if policy == DistancePolicy::Dot && norm_cache.is_none() {
                        match shard_norms(
                            self.source.as_ref(),
                            self.lo,
                            self.hi,
                            self.chunk_rows,
                            d,
                        ) {
                            Ok(norms) => norm_cache = Some(norms),
                            Err(e) => {
                                let msg = format!("shard norm pass failed: {e}");
                                let _ = wire::write_frame(
                                    &mut stream,
                                    &Frame::ErrMsg { message: msg },
                                );
                                return Err(e);
                            }
                        }
                    }
                    let x_norms = match policy {
                        DistancePolicy::Dot => norm_cache.as_deref(),
                        DistancePolicy::Exact => None,
                    };
                    if let Err(e) = stream_shard(
                        self.source.as_ref(),
                        self.lo,
                        self.hi,
                        self.chunk_rows,
                        d,
                        &centroids,
                        k,
                        &mut assign,
                        stats,
                        policy,
                        x_norms,
                    ) {
                        // tell the leader why before the session dies,
                        // so its error names the worker-side cause
                        let msg = format!("shard read failed: {e}");
                        let _ = wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg });
                        return Err(e);
                    }
                    wire::write_frame(
                        &mut stream,
                        &Frame::Partials {
                            k: k as u32,
                            dim: d as u32,
                            counts: stats.counts.clone(),
                            sums: stats.sums.clone(),
                            sse: stats.sse,
                        },
                    )?;
                }
                Frame::Gather { indices } => {
                    if let Some(&bad) = indices.iter().find(|&&i| i >= n as u64) {
                        wire::write_frame(
                            &mut stream,
                            &Frame::ErrMsg {
                                message: format!("gather: row {bad} out of range (shard n = {n})"),
                            },
                        )?;
                        continue;
                    }
                    // shard-local → source-global row indices
                    let global: Vec<usize> =
                        indices.iter().map(|&i| self.lo + i as usize).collect();
                    let rows = self.source.gather(&global)?;
                    wire::write_frame(&mut stream, &Frame::Rows { dim: d as u32, rows })?;
                }
                Frame::FetchAssign => {
                    wire::write_frame(&mut stream, &Frame::AssignShard { assign: assign.clone() })?;
                }
                Frame::Shutdown => return Ok(()),
                other => {
                    let msg = format!("unexpected {} frame from the leader", other.name());
                    wire::write_frame(&mut stream, &Frame::ErrMsg { message: msg.clone() })?;
                    return Err(Error::Cluster(ClusterError::Protocol(msg)));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::source::OwnedMemorySource;
    use crate::data::MixtureSpec;

    fn worker(n: usize) -> ShardWorker {
        let ds = MixtureSpec::paper_2d(4).generate(n, 3);
        ShardWorker::new(Box::new(OwnedMemorySource::new(ds)), 64).unwrap()
    }

    #[test]
    fn construction_validates() {
        let ds = MixtureSpec::paper_2d(4).generate(100, 3);
        let src = || Box::new(OwnedMemorySource::new(ds.clone()));
        assert!(ShardWorker::new(src(), 0).is_err()); // zero chunk
        assert!(ShardWorker::with_range(src(), 50, 40, 8).is_err()); // inverted
        assert!(ShardWorker::with_range(src(), 0, 101, 8).is_err()); // past n
        let w = ShardWorker::with_range(src(), 25, 75, 8).unwrap();
        assert_eq!(w.rows(), 50);
        assert!(w.describe().contains("[25, 75)"), "{}", w.describe());
    }

    #[test]
    fn shard_slice_matches_shard_ranges() {
        assert_eq!(ShardWorker::shard_slice(10, 0, 3).unwrap(), (0, 4));
        assert_eq!(ShardWorker::shard_slice(10, 1, 3).unwrap(), (4, 7));
        assert_eq!(ShardWorker::shard_slice(10, 2, 3).unwrap(), (7, 10));
        assert!(ShardWorker::shard_slice(10, 3, 3).is_err());
        assert!(ShardWorker::shard_slice(10, 0, 0).is_err());
    }

    /// Drive one session over a real localhost socket pair — the
    /// protocol exercised without the leader engine.
    #[test]
    fn session_answers_every_frame_kind() {
        let w = worker(100);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();

            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let spec = wire::read_frame(&mut conn, "spec").unwrap().0;
            assert_eq!(spec, Frame::ShardSpec { rows: 100, dim: 2 });

            wire::write_frame(&mut conn, &Frame::Gather { indices: vec![5, 0, 99] }).unwrap();
            match wire::read_frame(&mut conn, "rows").unwrap().0 {
                Frame::Rows { dim: 2, rows } => assert_eq!(rows.len(), 6),
                other => panic!("unexpected {other:?}"),
            }

            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 2,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0, 0.0, 10.0, 10.0],
                },
            )
            .unwrap();
            let exact_partials = match wire::read_frame(&mut conn, "partials").unwrap().0 {
                Frame::Partials { k: 2, dim: 2, counts, sums, sse } => {
                    assert_eq!(counts.iter().sum::<u64>(), 100);
                    assert_eq!(sums.len(), 4);
                    (counts, sums, sse)
                }
                other => panic!("unexpected {other:?}"),
            };

            // a dot-policy Assign on the same session: the full
            // partition still comes back, with SSE tolerance-close to
            // the exact pass (a razor-edge point may pick the other of
            // two near-equidistant centroids, so counts are not byte-
            // compared here — integration_distance.rs pins the strong
            // contract on the converged paper suites)
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 2,
                    dim: 2,
                    policy: DistancePolicy::Dot,
                    centroids: vec![0.0, 0.0, 10.0, 10.0],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "dot partials").unwrap().0 {
                Frame::Partials { k: 2, dim: 2, counts, sums, sse } => {
                    assert_eq!(counts.iter().sum::<u64>(), 100);
                    assert_eq!(sums.len(), 4);
                    let rel = (sse - exact_partials.2).abs() / exact_partials.2.max(1.0);
                    assert!(rel < 1e-3, "dot sse {sse} vs exact {}", exact_partials.2);
                }
                other => panic!("unexpected {other:?}"),
            }

            wire::write_frame(&mut conn, &Frame::FetchAssign).unwrap();
            match wire::read_frame(&mut conn, "assign").unwrap().0 {
                Frame::AssignShard { assign } => {
                    assert_eq!(assign.len(), 100);
                    assert!(assign.iter().all(|&a| a == 0 || a == 1));
                }
                other => panic!("unexpected {other:?}"),
            }

            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn dim_mismatch_gets_errmsg_session_survives() {
        let w = worker(50);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            // 3D centroids at a 2D shard
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 1,
                    dim: 3,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0; 3],
                },
            )
            .unwrap();
            match wire::read_frame(&mut conn, "err").unwrap().0 {
                Frame::ErrMsg { message } => assert!(message.contains("2D"), "{message}"),
                other => panic!("unexpected {other:?}"),
            }
            // the session is still alive: a correct Assign now works
            wire::write_frame(
                &mut conn,
                &Frame::Assign {
                    k: 1,
                    dim: 2,
                    policy: DistancePolicy::Exact,
                    centroids: vec![0.0; 2],
                },
            )
            .unwrap();
            assert!(matches!(
                wire::read_frame(&mut conn, "partials").unwrap().0,
                Frame::Partials { .. }
            ));
            wire::write_frame(&mut conn, &Frame::Shutdown).unwrap();
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn leader_disconnect_ends_session_cleanly() {
        let w = worker(20);
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let handle = std::thread::spawn(move || {
            let mut conn = TcpStream::connect(addr).unwrap();
            wire::write_frame(&mut conn, &Frame::Hello { version: WIRE_VERSION }).unwrap();
            let _ = wire::read_frame(&mut conn, "spec").unwrap();
            // drop without Shutdown — a dying leader
        });
        w.serve_listener(&listener, true).unwrap();
        handle.join().unwrap();
    }
}
