//! Length-prefixed binary frames for the distributed Lloyd protocol
//! (DESIGN.md §10).
//!
//! One frame = `len: u32 LE` (type byte + payload), `type: u8`,
//! payload. All multi-byte values are little-endian; f32/f64 travel as
//! their IEEE-754 bit patterns, so centroids and partial statistics
//! cross the wire losslessly — the foundation of the `dist ≡ threads ≡
//! oocore` bit-identity contract.
//!
//! The conversation (leader drives, worker answers):
//!
//! ```text
//! leader                          worker
//!   Hello{version}        ──►
//!                         ◄──    ShardSpec{rows, dim}
//!   Gather{indices}       ──►                        (init only)
//!                         ◄──    Rows{dim, rows}
//!   ┌ per iteration ───────────────────────────────┐
//!   │ Assign{k, dim, policy, μ}  ──►               │
//!   │                    ◄──  Partials{counts,     │
//!   │                          sums, sse}          │
//!   └──────────────────────────────────────────────┘
//!   FetchAssign           ──►
//!                         ◄──    AssignShard{assign}
//!   Shutdown              ──►                        (session ends)
//! ```
//!
//! The elastic scheduler (DESIGN.md §12) replaces the per-shard
//! `Assign`/`Partials` round with chunk-granular dispatch on the same
//! session; a leader reconnecting after a failure opens the new session
//! with `Rejoin` instead of `Hello` (identical semantics — the split
//! exists so telemetry and logs can tell a recovery from a cold start):
//!
//! ```text
//! leader                          worker (full-view)
//!   Hello{version} | Rejoin{version}  ──►
//!                         ◄──    ShardSpec{rows, dim}
//!   ┌ per chunk unit ──────────────────────────────────┐
//!   │ ChunkAssign{chunk, lo, hi, k, dim,               │
//!   │             policy, want_assign, μ}  ──►         │
//!   │             ◄──  ChunkPartials{chunk, counts,    │
//!   │                   sums, sse, assign?}            │
//!   └──────────────────────────────────────────────────┘
//!   Shutdown              ──►                        (session ends)
//! ```
//!
//! A worker that cannot satisfy a request answers `ErrMsg{..}` instead;
//! the leader converts it to [`ClusterError::Protocol`] and fails fast.
//! Readers enforce [`MAX_FRAME_BYTES`] and reject unknown types or
//! short payloads with [`ClusterError::Frame`] — corrupt bytes are a
//! typed error, never a hang or an attacker-sized allocation.

use std::io::{Read, Write};

use crate::error::{ClusterError, Error, Result};
use crate::linalg::kernel::DistancePolicy;
use crate::util::chaos;

/// Protocol version carried in [`Frame::Hello`]; bumped on any frame
/// layout change so mismatched binaries fail the handshake typed.
/// v2: `Assign` carries the distance policy byte (DESIGN.md §11).
/// v3: chunk-granular elastic frames `ChunkAssign` / `ChunkPartials` /
/// `Rejoin` (DESIGN.md §12).
/// v4: `Partials` / `ChunkPartials` may carry an optional trailing
/// [`PhaseNs`] timing block (DESIGN.md §15). The block is omitted when
/// absent, so a v4 frame without timings is byte-identical to v3 —
/// which is why [`MIN_WIRE_VERSION`] peers still interoperate.
pub const WIRE_VERSION: u16 = 4;

/// Oldest peer version a v4 binary will still talk to. v3 frames are a
/// strict byte-prefix subset of v4 (the phase block is optional and
/// trailing), so the handshake accepts `MIN_WIRE_VERSION..=WIRE_VERSION`
/// and simply never attaches timings on a v3 session.
pub const MIN_WIRE_VERSION: u16 = 3;

/// Upper bound on `len` a reader will accept (1 GiB): a corrupt or
/// hostile length prefix becomes [`ClusterError::Frame`] instead of a
/// giant allocation.
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

const T_HELLO: u8 = 1;
const T_SHARD_SPEC: u8 = 2;
const T_ASSIGN: u8 = 3;
const T_PARTIALS: u8 = 4;
const T_GATHER: u8 = 5;
const T_ROWS: u8 = 6;
const T_FETCH_ASSIGN: u8 = 7;
const T_ASSIGN_SHARD: u8 = 8;
const T_SHUTDOWN: u8 = 9;
const T_ERR_MSG: u8 = 10;
const T_CHUNK_ASSIGN: u8 = 11;
const T_CHUNK_PARTIALS: u8 = 12;
const T_REJOIN: u8 = 13;

/// Marker byte opening the optional trailing phase block (v4); any
/// other value where a phase block could start is a typed frame error.
const PHASE_MARKER: u8 = 1;

/// Shard-side phase timings piggybacked on `Partials` /
/// `ChunkPartials` (wire v4, DESIGN.md §15): nanoseconds the worker
/// spent in the assign/accumulate fold and serializing the reply.
/// Observability only — never consulted by the numeric fold, so the
/// bit-identity contracts are indifferent to its presence.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct PhaseNs {
    pub assign_ns: u64,
    pub ser_ns: u64,
}

/// One protocol message (module docs: the conversation).
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Leader → worker: opens a session.
    Hello { version: u16 },
    /// Worker → leader: shard size and dimensionality.
    ShardSpec { rows: u64, dim: u32 },
    /// Leader → worker: compute one E-step against these centroids
    /// (`k × dim` row-major f32) under the given distance policy
    /// (0 = exact, 1 = dot on the wire).
    Assign { k: u32, dim: u32, policy: DistancePolicy, centroids: Vec<f32> },
    /// Worker → leader: the shard's partial statistics for the last
    /// `Assign` (`k` counts, `k × dim` f64 sums, shard SSE). `phase`
    /// (v4) optionally carries the worker's own phase timings; `None`
    /// encodes byte-identically to a v3 frame.
    Partials {
        k: u32,
        dim: u32,
        counts: Vec<u64>,
        sums: Vec<f64>,
        sse: f64,
        phase: Option<PhaseNs>,
    },
    /// Leader → worker: fetch these shard-local rows (init gather).
    Gather { indices: Vec<u64> },
    /// Worker → leader: the gathered rows, request order.
    Rows { dim: u32, rows: Vec<f32> },
    /// Leader → worker: send the shard's current assignment vector.
    FetchAssign,
    /// Worker → leader: shard-local assignments in row order.
    AssignShard { assign: Vec<i32> },
    /// Leader → worker: end the session.
    Shutdown,
    /// Worker → leader: a request could not be satisfied.
    ErrMsg { message: String },
    /// Leader → worker (elastic, v3): compute the E-step for one chunk
    /// of the deterministic [`crate::kmeans::sched`] grid. `lo`/`hi`
    /// are the chunk's global row range — redundant with `chunk` given
    /// `n`, carried so the worker can verify both sides agree on the
    /// grid. `want_assign` (0/1) asks for the chunk's assignment vector
    /// in the reply (the final collection pass).
    ChunkAssign {
        chunk: u64,
        lo: u64,
        hi: u64,
        k: u32,
        dim: u32,
        policy: DistancePolicy,
        want_assign: bool,
        centroids: Vec<f32>,
    },
    /// Worker → leader (elastic, v3): the chunk's zero-seeded partial
    /// statistics (`k` counts, `k × dim` f64 sums, chunk SSE), keyed by
    /// the chunk id so re-dispatched and speculative replies can be
    /// matched regardless of arrival order. `assign` is empty unless
    /// the request set `want_assign`. `phase` (v4) optionally carries
    /// the worker's own phase timings; `None` encodes byte-identically
    /// to a v3 frame.
    ChunkPartials {
        chunk: u64,
        k: u32,
        dim: u32,
        counts: Vec<u64>,
        sums: Vec<f64>,
        sse: f64,
        assign: Vec<i32>,
        phase: Option<PhaseNs>,
    },
    /// Leader → worker (elastic, v3): opens a *replacement* session
    /// after a connection loss — handled exactly like [`Frame::Hello`],
    /// but lets the worker log a recovery rather than a cold start.
    Rejoin { version: u16 },
}

fn frame_err(msg: impl Into<String>) -> Error {
    Error::Cluster(ClusterError::Frame(msg.into()))
}

fn conn_err(msg: impl Into<String>) -> Error {
    Error::Cluster(ClusterError::Connection(msg.into()))
}

/// Map an IO failure during a frame read/write to the cluster taxonomy:
/// timeouts and resets are [`ClusterError::Connection`]. `what` names
/// the operation and direction ("sending Assign", "reading frame
/// body") so a stalled write is not misreported as a read stall.
fn io_err(e: std::io::Error, what: &str) -> Error {
    use std::io::ErrorKind;
    match e.kind() {
        ErrorKind::WouldBlock | ErrorKind::TimedOut => conn_err(format!("{what}: timed out")),
        _ => conn_err(format!("{what}: {e}")),
    }
}

// ---- payload encoding helpers ------------------------------------------

fn push_u16(buf: &mut Vec<u8>, v: u16) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// Append the optional v4 phase block: marker byte + two u64s when
/// present, *nothing* when absent — `None` frames stay byte-identical
/// to their v3 encodings.
fn push_phase(buf: &mut Vec<u8>, phase: &Option<PhaseNs>) {
    if let Some(p) = phase {
        buf.push(PHASE_MARKER);
        push_u64(buf, p.assign_ns);
        push_u64(buf, p.ser_ns);
    }
}

/// Bounded-payload cursor: every `take_*` is a typed frame error when
/// the payload runs short, so a truncated frame can never panic.
struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn new(b: &'a [u8]) -> Cursor<'a> {
        Cursor { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            return Err(frame_err(format!(
                "payload too short: wanted {n} more bytes at offset {}, have {}",
                self.i,
                self.b.len()
            )));
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u16(&mut self) -> Result<u16> {
        let s = self.take(2)?;
        Ok(u16::from_le_bytes([s[0], s[1]]))
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u64(&mut self) -> Result<u64> {
        let s = self.take(8)?;
        Ok(u64::from_le_bytes([s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7]]))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| frame_err("f32 count overflows"))?)?;
        Ok(s.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn f64s(&mut self, n: usize) -> Result<Vec<f64>> {
        let s = self.take(n.checked_mul(8).ok_or_else(|| frame_err("f64 count overflows"))?)?;
        Ok(s.chunks_exact(8)
            .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let s = self.take(n.checked_mul(8).ok_or_else(|| frame_err("u64 count overflows"))?)?;
        Ok(s.chunks_exact(8)
            .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
            .collect())
    }

    fn i32s(&mut self, n: usize) -> Result<Vec<i32>> {
        let s = self.take(n.checked_mul(4).ok_or_else(|| frame_err("i32 count overflows"))?)?;
        Ok(s.chunks_exact(4).map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Decode the optional trailing [`PhaseNs`] block (wire v4). A v3
    /// frame ends exactly where this is called — `None`. Any bytes
    /// beyond that must be a complete, well-marked phase block;
    /// truncation or a bad marker is a typed frame error (the
    /// subsequent `finish()` rejects anything after the block).
    fn opt_phase(&mut self, what: &str) -> Result<Option<PhaseNs>> {
        if self.remaining() == 0 {
            return Ok(None);
        }
        let marker = self.take(1)?[0];
        if marker != PHASE_MARKER {
            return Err(frame_err(format!("{what}: bad phase block marker {marker}")));
        }
        Ok(Some(PhaseNs { assign_ns: self.u64()?, ser_ns: self.u64()? }))
    }

    fn finish(&self) -> Result<()> {
        if self.i != self.b.len() {
            return Err(frame_err(format!(
                "{} trailing payload bytes after a complete frame",
                self.b.len() - self.i
            )));
        }
        Ok(())
    }
}

impl Frame {
    fn type_byte(&self) -> u8 {
        match self {
            Frame::Hello { .. } => T_HELLO,
            Frame::ShardSpec { .. } => T_SHARD_SPEC,
            Frame::Assign { .. } => T_ASSIGN,
            Frame::Partials { .. } => T_PARTIALS,
            Frame::Gather { .. } => T_GATHER,
            Frame::Rows { .. } => T_ROWS,
            Frame::FetchAssign => T_FETCH_ASSIGN,
            Frame::AssignShard { .. } => T_ASSIGN_SHARD,
            Frame::Shutdown => T_SHUTDOWN,
            Frame::ErrMsg { .. } => T_ERR_MSG,
            Frame::ChunkAssign { .. } => T_CHUNK_ASSIGN,
            Frame::ChunkPartials { .. } => T_CHUNK_PARTIALS,
            Frame::Rejoin { .. } => T_REJOIN,
        }
    }

    /// Human name for error messages ("expected Partials, got X").
    pub fn name(&self) -> &'static str {
        match self {
            Frame::Hello { .. } => "Hello",
            Frame::ShardSpec { .. } => "ShardSpec",
            Frame::Assign { .. } => "Assign",
            Frame::Partials { .. } => "Partials",
            Frame::Gather { .. } => "Gather",
            Frame::Rows { .. } => "Rows",
            Frame::FetchAssign => "FetchAssign",
            Frame::AssignShard { .. } => "AssignShard",
            Frame::Shutdown => "Shutdown",
            Frame::ErrMsg { .. } => "ErrMsg",
            Frame::ChunkAssign { .. } => "ChunkAssign",
            Frame::ChunkPartials { .. } => "ChunkPartials",
            Frame::Rejoin { .. } => "Rejoin",
        }
    }

    fn payload(&self) -> Vec<u8> {
        let mut b = Vec::new();
        match self {
            Frame::Hello { version } => push_u16(&mut b, *version),
            Frame::ShardSpec { rows, dim } => {
                push_u64(&mut b, *rows);
                push_u32(&mut b, *dim);
            }
            Frame::Assign { k, dim, policy, centroids } => {
                push_u32(&mut b, *k);
                push_u32(&mut b, *dim);
                b.push(match policy {
                    DistancePolicy::Exact => 0,
                    DistancePolicy::Dot => 1,
                });
                for v in centroids {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::Partials { k, dim, counts, sums, sse, phase } => {
                push_u32(&mut b, *k);
                push_u32(&mut b, *dim);
                for c in counts {
                    push_u64(&mut b, *c);
                }
                for s in sums {
                    push_u64(&mut b, s.to_bits());
                }
                push_u64(&mut b, sse.to_bits());
                push_phase(&mut b, phase);
            }
            Frame::Gather { indices } => {
                push_u32(&mut b, indices.len() as u32);
                for i in indices {
                    push_u64(&mut b, *i);
                }
            }
            Frame::Rows { dim, rows } => {
                push_u32(&mut b, *dim);
                push_u32(&mut b, (rows.len() / (*dim).max(1) as usize) as u32);
                for v in rows {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::FetchAssign | Frame::Shutdown => {}
            Frame::AssignShard { assign } => {
                push_u64(&mut b, assign.len() as u64);
                for a in assign {
                    b.extend_from_slice(&a.to_le_bytes());
                }
            }
            Frame::ErrMsg { message } => b.extend_from_slice(message.as_bytes()),
            Frame::ChunkAssign { chunk, lo, hi, k, dim, policy, want_assign, centroids } => {
                push_u64(&mut b, *chunk);
                push_u64(&mut b, *lo);
                push_u64(&mut b, *hi);
                push_u32(&mut b, *k);
                push_u32(&mut b, *dim);
                b.push(match policy {
                    DistancePolicy::Exact => 0,
                    DistancePolicy::Dot => 1,
                });
                b.push(u8::from(*want_assign));
                for v in centroids {
                    b.extend_from_slice(&v.to_le_bytes());
                }
            }
            Frame::ChunkPartials { chunk, k, dim, counts, sums, sse, assign, phase } => {
                push_u64(&mut b, *chunk);
                push_u32(&mut b, *k);
                push_u32(&mut b, *dim);
                for c in counts {
                    push_u64(&mut b, *c);
                }
                for s in sums {
                    push_u64(&mut b, s.to_bits());
                }
                push_u64(&mut b, sse.to_bits());
                push_u64(&mut b, assign.len() as u64);
                for a in assign {
                    b.extend_from_slice(&a.to_le_bytes());
                }
                push_phase(&mut b, phase);
            }
            Frame::Rejoin { version } => push_u16(&mut b, *version),
        }
        b
    }

    fn parse(ty: u8, payload: &[u8]) -> Result<Frame> {
        let mut c = Cursor::new(payload);
        let f = match ty {
            T_HELLO => Frame::Hello { version: c.u16()? },
            T_SHARD_SPEC => Frame::ShardSpec { rows: c.u64()?, dim: c.u32()? },
            T_ASSIGN => {
                let k = c.u32()?;
                let dim = c.u32()?;
                let policy = match c.take(1)?[0] {
                    0 => DistancePolicy::Exact,
                    1 => DistancePolicy::Dot,
                    other => {
                        return Err(frame_err(format!("Assign: unknown distance policy {other}")))
                    }
                };
                let want = (k as usize)
                    .checked_mul(dim as usize)
                    .ok_or_else(|| frame_err("Assign: k × dim overflows"))?;
                Frame::Assign { k, dim, policy, centroids: c.f32s(want)? }
            }
            T_PARTIALS => {
                let k = c.u32()?;
                let dim = c.u32()?;
                let kd = (k as usize)
                    .checked_mul(dim as usize)
                    .ok_or_else(|| frame_err("Partials: k × dim overflows"))?;
                let counts = c.u64s(k as usize)?;
                let sums = c.f64s(kd)?;
                let sse = c.f64()?;
                let phase = c.opt_phase("Partials")?;
                Frame::Partials { k, dim, counts, sums, sse, phase }
            }
            T_GATHER => {
                let m = c.u32()? as usize;
                Frame::Gather { indices: c.u64s(m)? }
            }
            T_ROWS => {
                let dim = c.u32()?;
                let m = c.u32()? as usize;
                let want = m
                    .checked_mul(dim as usize)
                    .ok_or_else(|| frame_err("Rows: m × dim overflows"))?;
                Frame::Rows { dim, rows: c.f32s(want)? }
            }
            T_FETCH_ASSIGN => Frame::FetchAssign,
            T_ASSIGN_SHARD => {
                let n = c.u64()?;
                let n = usize::try_from(n)
                    .map_err(|_| frame_err(format!("AssignShard: implausible n = {n}")))?;
                Frame::AssignShard { assign: c.i32s(n)? }
            }
            T_SHUTDOWN => Frame::Shutdown,
            T_ERR_MSG => Frame::ErrMsg {
                message: String::from_utf8_lossy(c.take(payload.len())?).into_owned(),
            },
            T_CHUNK_ASSIGN => {
                let chunk = c.u64()?;
                let lo = c.u64()?;
                let hi = c.u64()?;
                let k = c.u32()?;
                let dim = c.u32()?;
                let policy = match c.take(1)?[0] {
                    0 => DistancePolicy::Exact,
                    1 => DistancePolicy::Dot,
                    other => {
                        return Err(frame_err(format!(
                            "ChunkAssign: unknown distance policy {other}"
                        )))
                    }
                };
                let want_assign = match c.take(1)?[0] {
                    0 => false,
                    1 => true,
                    other => {
                        return Err(frame_err(format!(
                            "ChunkAssign: bad want_assign byte {other}"
                        )))
                    }
                };
                let want = (k as usize)
                    .checked_mul(dim as usize)
                    .ok_or_else(|| frame_err("ChunkAssign: k × dim overflows"))?;
                Frame::ChunkAssign {
                    chunk,
                    lo,
                    hi,
                    k,
                    dim,
                    policy,
                    want_assign,
                    centroids: c.f32s(want)?,
                }
            }
            T_CHUNK_PARTIALS => {
                let chunk = c.u64()?;
                let k = c.u32()?;
                let dim = c.u32()?;
                let kd = (k as usize)
                    .checked_mul(dim as usize)
                    .ok_or_else(|| frame_err("ChunkPartials: k × dim overflows"))?;
                let counts = c.u64s(k as usize)?;
                let sums = c.f64s(kd)?;
                let sse = c.f64()?;
                let m = c.u64()?;
                let m = usize::try_from(m)
                    .map_err(|_| frame_err(format!("ChunkPartials: implausible assign len {m}")))?;
                let assign = c.i32s(m)?;
                let phase = c.opt_phase("ChunkPartials")?;
                Frame::ChunkPartials { chunk, k, dim, counts, sums, sse, assign, phase }
            }
            T_REJOIN => Frame::Rejoin { version: c.u16()? },
            other => return Err(frame_err(format!("unknown frame type {other}"))),
        };
        c.finish()?;
        Ok(f)
    }
}

/// Write one frame, returning the wire bytes it occupied (length prefix
/// included). Assembles the frame in one buffer so the OS sees a single
/// write — no interleaving hazards, one syscall for small frames.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> Result<u64> {
    let payload = frame.payload();
    let len = 1u64 + payload.len() as u64;
    if len > MAX_FRAME_BYTES as u64 {
        return Err(frame_err(format!("frame of {len} bytes exceeds MAX_FRAME_BYTES")));
    }
    let mut buf = Vec::with_capacity(5 + payload.len());
    push_u32(&mut buf, len as u32);
    buf.push(frame.type_byte());
    buf.extend_from_slice(&payload);
    let what = format!("sending {}", frame.name());
    if let Some(fault) = chaos::hit(chaos::Site::WireWrite) {
        let full = buf.len();
        match chaos::apply_to_bytes(chaos::Site::WireWrite, fault, &mut buf) {
            Some(_) => return Err(conn_err(format!("chaos: injected write failure while {what}"))),
            None if buf.len() < full => {
                // Mid-frame close: the peer sees a truncated frame and
                // must surface a typed error, never hang or misparse.
                w.write_all(&buf).map_err(|e| io_err(e, &what))?;
                w.flush().map_err(|e| io_err(e, &what))?;
                return Err(conn_err(format!("chaos: injected mid-frame close while {what}")));
            }
            None => {} // stall already slept; proceed with the full frame
        }
    }
    w.write_all(&buf).map_err(|e| io_err(e, &what))?;
    w.flush().map_err(|e| io_err(e, &what))?;
    Ok(buf.len() as u64)
}

/// Read one frame, returning it with the wire bytes it occupied.
/// A peer that closes the stream *between* frames yields `Ok(None)`
/// (clean end of session) — whether the close arrives as an orderly
/// EOF or as a connection reset/abort (a leader that exits without
/// draining its receive buffer makes the kernel send RST, not FIN;
/// the frame-boundary rule treats both as the same event). EOF or a
/// reset *inside* a frame, a bad length prefix, an unknown type or a
/// short payload are typed [`Error::Cluster`] errors.
pub fn read_frame_opt(r: &mut impl Read) -> Result<Option<(Frame, u64)>> {
    use std::io::ErrorKind;
    if let Some(fault) = chaos::hit(chaos::Site::WireRead) {
        if let chaos::Fault::Stall { ms } = fault {
            std::thread::sleep(std::time::Duration::from_millis(ms as u64));
        } else {
            return Err(conn_err("chaos: injected connection failure while reading a frame"));
        }
    }
    let mut len_buf = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        let n = match r.read(&mut len_buf[got..]) {
            Ok(n) => n,
            Err(e)
                if got == 0
                    && matches!(
                        e.kind(),
                        ErrorKind::ConnectionReset | ErrorKind::ConnectionAborted
                    ) =>
            {
                return Ok(None); // reset at a frame boundary = clean close
            }
            Err(e) => return Err(io_err(e, "reading frame header")),
        };
        if n == 0 {
            if got == 0 {
                return Ok(None); // clean close at a frame boundary
            }
            return Err(frame_err("eof inside a frame length prefix"));
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf);
    if len == 0 || len > MAX_FRAME_BYTES {
        return Err(frame_err(format!("implausible frame length {len}")));
    }
    // Read the body incrementally in bounded chunks instead of
    // allocating `len` bytes up front: a forged length prefix (up to
    // MAX_FRAME_BYTES = 1 GiB) must not translate into an
    // attacker-sized allocation before a single payload byte arrives.
    // The buffer only grows as fast as the peer actually sends.
    const BODY_CHUNK: usize = 64 * 1024;
    let len_usize = len as usize;
    let mut body: Vec<u8> = Vec::with_capacity(len_usize.min(BODY_CHUNK));
    let mut chunk = [0u8; BODY_CHUNK];
    while body.len() < len_usize {
        let want = (len_usize - body.len()).min(BODY_CHUNK);
        match r.read(&mut chunk[..want]) {
            Ok(0) => {
                return Err(frame_err(format!(
                    "truncated frame: length prefix promises {len} bytes"
                )))
            }
            Ok(n) => body.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(io_err(e, "reading frame body")),
        }
    }
    let frame = Frame::parse(body[0], &body[1..])?;
    Ok(Some((frame, 4 + len as u64)))
}

/// [`read_frame_opt`] for callers mid-conversation, where a clean close
/// is itself a failure (the peer vanished while a reply was owed).
pub fn read_frame(r: &mut impl Read, expect: &str) -> Result<(Frame, u64)> {
    read_frame_opt(r)?
        .ok_or_else(|| conn_err(format!("peer closed the connection while {expect}")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::error::ClusterError;

    fn roundtrip(f: Frame) {
        let mut buf = Vec::new();
        let wrote = write_frame(&mut buf, &f).unwrap();
        assert_eq!(wrote as usize, buf.len());
        let mut r = &buf[..];
        let (back, read) = read_frame(&mut r, "roundtrip").unwrap();
        assert_eq!(read, wrote);
        assert_eq!(back, f);
        assert!(r.is_empty(), "reader consumed exactly one frame");
    }

    #[test]
    fn every_frame_roundtrips() {
        roundtrip(Frame::Hello { version: WIRE_VERSION });
        roundtrip(Frame::ShardSpec { rows: 12345, dim: 3 });
        roundtrip(Frame::Assign {
            k: 2,
            dim: 3,
            policy: DistancePolicy::Exact,
            centroids: vec![1.5, -2.0, 0.0, 3.25, 4.0, 5.0],
        });
        roundtrip(Frame::Assign {
            k: 1,
            dim: 2,
            policy: DistancePolicy::Dot,
            centroids: vec![0.5, -0.5],
        });
        roundtrip(Frame::Partials {
            k: 2,
            dim: 2,
            counts: vec![7, 0],
            sums: vec![1.0, -0.5, 0.0, 1e300],
            sse: 42.0625,
            phase: None,
        });
        roundtrip(Frame::Partials {
            k: 2,
            dim: 2,
            counts: vec![7, 0],
            sums: vec![1.0, -0.5, 0.0, 1e300],
            sse: 42.0625,
            phase: Some(PhaseNs { assign_ns: 1_234_567, ser_ns: 890 }),
        });
        roundtrip(Frame::Gather { indices: vec![0, 99, 3] });
        roundtrip(Frame::Rows { dim: 2, rows: vec![1.0, 2.0, 3.0, 4.0] });
        roundtrip(Frame::FetchAssign);
        roundtrip(Frame::AssignShard { assign: vec![0, -1, 3, i32::MAX] });
        roundtrip(Frame::Shutdown);
        roundtrip(Frame::ErrMsg { message: "shard is 2D, leader sent 3D".into() });
        roundtrip(Frame::Rejoin { version: WIRE_VERSION });
        roundtrip(Frame::ChunkAssign {
            chunk: 17,
            lo: 17 * 1024,
            hi: 17 * 1024 + 513,
            k: 2,
            dim: 3,
            policy: DistancePolicy::Dot,
            want_assign: true,
            centroids: vec![1.5, -2.0, 0.0, 3.25, 4.0, 5.0],
        });
        roundtrip(Frame::ChunkPartials {
            chunk: 17,
            k: 2,
            dim: 2,
            counts: vec![7, 0],
            sums: vec![1.0, -0.5, 0.0, 1e300],
            sse: 42.0625,
            assign: vec![0, 1, -1],
            phase: Some(PhaseNs { assign_ns: u64::MAX, ser_ns: 0 }),
        });
        roundtrip(Frame::ChunkPartials {
            chunk: 0,
            k: 1,
            dim: 1,
            counts: vec![3],
            sums: vec![0.5],
            sse: 0.0,
            assign: vec![], // no want_assign: empty vector, not absent
            phase: None,
        });
    }

    #[test]
    fn phaseless_v4_frames_are_byte_identical_to_v3() {
        // v3 interop hinges on None adding zero bytes: the payload of a
        // phaseless Partials must end exactly at the sse field
        let f = Frame::Partials {
            k: 1,
            dim: 1,
            counts: vec![5],
            sums: vec![2.5],
            sse: 0.25,
            phase: None,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();
        // 4 len + 1 type + 4 k + 4 dim + 8 count + 8 sum + 8 sse
        assert_eq!(buf.len(), 4 + 1 + 4 + 4 + 8 + 8 + 8);
        // and a v3-layout byte stream (no phase block) decodes as None
        let (back, _) = read_frame(&mut &buf[..], "v3 layout").unwrap();
        assert_eq!(back, f);

        let g = Frame::ChunkPartials {
            chunk: 9,
            k: 1,
            dim: 1,
            counts: vec![5],
            sums: vec![2.5],
            sse: 0.25,
            assign: vec![3],
            phase: None,
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &g).unwrap();
        // + 8 chunk + 8 assign len + 4 one assign slot
        assert_eq!(buf.len(), 4 + 1 + 8 + 4 + 4 + 8 + 8 + 8 + 8 + 4);
    }

    #[test]
    fn truncated_or_mutated_phase_block_is_typed() {
        let f = Frame::Partials {
            k: 1,
            dim: 1,
            counts: vec![5],
            sums: vec![2.5],
            sse: 0.25,
            phase: Some(PhaseNs { assign_ns: 77, ser_ns: 88 }),
        };
        let mut buf = Vec::new();
        write_frame(&mut buf, &f).unwrap();

        // cut anywhere inside the 17-byte phase block: typed frame error
        for cut in 1..17 {
            let mut short = buf[..buf.len() - cut].to_vec();
            let new_len = (short.len() - 4) as u32;
            short[..4].copy_from_slice(&new_len.to_le_bytes());
            let err = read_frame_opt(&mut &short[..]).unwrap_err();
            assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "cut {cut}: {err}");
        }

        // corrupt the marker byte: typed, names the phase block
        let mut bad = buf.clone();
        let marker_at = bad.len() - 17;
        bad[marker_at] = 0xEE;
        let err = read_frame_opt(&mut &bad[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("phase block"), "{err}");

        // trailing garbage *after* a complete phase block stays typed
        let mut long = buf.clone();
        long.push(0xAB);
        let new_len = (long.len() - 4) as u32;
        long[..4].copy_from_slice(&new_len.to_le_bytes());
        let err = read_frame_opt(&mut &long[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn chunk_assign_rejects_bad_flag_bytes() {
        // want_assign must be 0 or 1; anything else is a frame error
        let mut payload = Vec::new();
        push_u64(&mut payload, 0); // chunk
        push_u64(&mut payload, 0); // lo
        push_u64(&mut payload, 8); // hi
        push_u32(&mut payload, 1); // k
        push_u32(&mut payload, 1); // dim
        payload.push(0); // policy: exact
        payload.push(7); // bogus want_assign
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        let mut buf = Vec::new();
        push_u32(&mut buf, 1 + payload.len() as u32);
        buf.push(T_CHUNK_ASSIGN);
        buf.extend_from_slice(&payload);
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("want_assign"), "{err}");
    }

    #[test]
    fn chunk_partials_short_payload_is_typed() {
        // declares an assignment vector it does not carry
        let mut payload = Vec::new();
        push_u64(&mut payload, 3); // chunk
        push_u32(&mut payload, 1); // k
        push_u32(&mut payload, 1); // dim
        push_u64(&mut payload, 5); // count
        push_u64(&mut payload, 1.0f64.to_bits()); // sum
        push_u64(&mut payload, 0.25f64.to_bits()); // sse
        push_u64(&mut payload, 10); // assign len — but no bytes follow
        let mut buf = Vec::new();
        push_u32(&mut buf, 1 + payload.len() as u32);
        buf.push(T_CHUNK_PARTIALS);
        buf.extend_from_slice(&payload);
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
    }

    /// A reader that fails with the given kind after yielding a prefix —
    /// models a peer that resets the connection mid-stream.
    struct ResettingReader {
        prefix: Vec<u8>,
        at: usize,
        kind: std::io::ErrorKind,
    }

    impl Read for ResettingReader {
        fn read(&mut self, out: &mut [u8]) -> std::io::Result<usize> {
            if self.at < self.prefix.len() {
                let n = out.len().min(self.prefix.len() - self.at);
                out[..n].copy_from_slice(&self.prefix[self.at..self.at + n]);
                self.at += n;
                Ok(n)
            } else {
                Err(std::io::Error::new(self.kind, "peer reset"))
            }
        }
    }

    #[test]
    fn reset_at_frame_boundary_is_clean_close() {
        use std::io::ErrorKind;
        // RST before any header byte: same as orderly EOF — Ok(None)
        for kind in [ErrorKind::ConnectionReset, ErrorKind::ConnectionAborted] {
            let mut r = ResettingReader { prefix: Vec::new(), at: 0, kind };
            assert!(read_frame_opt(&mut r).unwrap().is_none(), "{kind:?}");
        }
        // RST *inside* the length prefix: a reply was being framed —
        // that is a real connection error, not a clean close
        let mut r = ResettingReader {
            prefix: vec![1, 0],
            at: 0,
            kind: ErrorKind::ConnectionReset,
        };
        let err = read_frame_opt(&mut r).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");
        // RST inside a frame body likewise stays an error
        let mut full = Vec::new();
        write_frame(&mut full, &Frame::ShardSpec { rows: 9, dim: 2 }).unwrap();
        let mut r = ResettingReader {
            prefix: full[..6].to_vec(),
            at: 0,
            kind: ErrorKind::ConnectionReset,
        };
        let err = read_frame_opt(&mut r).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");
    }

    #[test]
    fn float_bits_survive_the_wire() {
        // the bit-identity contract depends on lossless float transport
        let weird = vec![f32::MIN_POSITIVE, -0.0, f32::NAN, 1.0000001];
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::Assign {
                k: 1,
                dim: 4,
                policy: DistancePolicy::Exact,
                centroids: weird.clone(),
            },
        )
        .unwrap();
        let (f, _) = read_frame(&mut &buf[..], "bits").unwrap();
        match f {
            Frame::Assign { centroids, .. } => {
                let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&centroids), bits(&weird));
            }
            other => panic!("unexpected {other:?}"),
        }
    }

    #[test]
    fn clean_close_is_none_mid_frame_is_error() {
        let mut empty: &[u8] = &[];
        assert!(read_frame_opt(&mut empty).unwrap().is_none());
        // a reply owed: clean close becomes a Connection error
        let mut empty2: &[u8] = &[];
        let err = read_frame(&mut empty2, "waiting for Partials").unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");

        // partial length prefix
        let mut short: &[u8] = &[1, 0];
        let err = read_frame_opt(&mut short).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
    }

    #[test]
    fn truncated_payload_is_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::ShardSpec { rows: 10, dim: 2 }).unwrap();
        let cut = &buf[..buf.len() - 3];
        let err = read_frame_opt(&mut &cut[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("truncated"), "{err}");
    }

    #[test]
    fn corrupt_type_and_length_are_typed() {
        // unknown type byte
        let buf = [1u8, 0, 0, 0, 0xEE];
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("unknown frame type"), "{err}");

        // zero length
        let buf = [0u8, 0, 0, 0];
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");

        // absurd length — must error before allocating
        let mut buf = Vec::new();
        push_u32(&mut buf, MAX_FRAME_BYTES + 1);
        buf.push(T_SHUTDOWN);
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("implausible"), "{err}");
    }

    #[test]
    fn unknown_distance_policy_byte_is_typed() {
        let mut payload = Vec::new();
        push_u32(&mut payload, 1); // k
        push_u32(&mut payload, 1); // dim
        payload.push(9); // bogus policy
        payload.extend_from_slice(&1.0f32.to_le_bytes());
        let mut buf = Vec::new();
        push_u32(&mut buf, 1 + payload.len() as u32);
        buf.push(T_ASSIGN);
        buf.extend_from_slice(&payload);
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("distance policy"), "{err}");
    }

    #[test]
    fn short_and_overlong_payloads_are_typed() {
        // Partials declaring k=2 but carrying bytes for k=1
        let mut payload = Vec::new();
        push_u32(&mut payload, 2); // k
        push_u32(&mut payload, 1); // dim
        push_u64(&mut payload, 5); // one count (of two)
        let mut buf = Vec::new();
        push_u32(&mut buf, 1 + payload.len() as u32);
        buf.push(T_PARTIALS);
        buf.extend_from_slice(&payload);
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");

        // Shutdown with trailing garbage
        let buf = [3u8, 0, 0, 0, T_SHUTDOWN, 0xAB, 0xCD];
        let err = read_frame_opt(&mut &buf[..]).unwrap_err();
        assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
        assert!(err.to_string().contains("trailing"), "{err}");
    }

    #[test]
    fn two_frames_stream_back_to_back() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Hello { version: 1 }).unwrap();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        let mut r = &buf[..];
        let (a, _) = read_frame(&mut r, "first").unwrap();
        let (b, _) = read_frame(&mut r, "second").unwrap();
        assert_eq!(a, Frame::Hello { version: 1 });
        assert_eq!(b, Frame::Shutdown);
        assert!(read_frame_opt(&mut r).unwrap().is_none());
    }
}
