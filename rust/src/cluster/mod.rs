//! Distributed K-Means over TCP shard workers (DESIGN.md §10).
//!
//! This is the paper's decomposition taken across the process/machine
//! boundary: the E-step shards cleanly once centroid updates are
//! race-free, so each worker process owns one data shard (any
//! [`crate::data::source::DataSource`]) and the leader only ever sees
//! `K × d`-sized statistics — the PKMeans-style structure of
//! arXiv:1608.06347, where nodes compute partial sums and a coordinator
//! merges them.
//!
//! Three pieces:
//!
//! - [`wire`] — length-prefixed binary frames (`Hello`/`ShardSpec`,
//!   `Assign` → `Partials`, `Gather` → `Rows`, `FetchAssign` →
//!   `AssignShard`, `Shutdown`, `ErrMsg`, and the elastic v3 trio
//!   `ChunkAssign` → `ChunkPartials` plus `Rejoin`); floats travel as
//!   IEEE bits, so nothing is lost in transit.
//! - [`worker`] — the `parakm worker` server: owns a shard, replays the
//!   out-of-core shard fold per `Assign`, answers with partials; a
//!   full-view worker additionally serves chunk-granular `ChunkAssign`
//!   requests for the elastic scheduler.
//! - [`loopback`] — in-process harness spawning worker threads on
//!   `127.0.0.1:0`, so `cargo test` exercises the full protocol,
//!   including scripted failure drills ([`worker::SessionFault`]).
//!
//! The leader engine lives in [`crate::kmeans::dist`] with the other
//! engines. Determinism: workers fold their rows in ascending order
//! through the chunked-accumulation contract and the leader merges
//! per-shard partials with [`crate::kmeans::step::merge_ordered`] in
//! ascending shard index — never in arrival order — so `dist(S)` is
//! bit-identical to `oocore(shards = S)` and `threads(p = S)` for any
//! worker count, any reply timing, and any mix of kernel tiers across
//! the cluster. The elastic scheduler keys the same fold by **chunk
//! id** instead of shard index (DESIGN.md §12), which extends the
//! guarantee across failures: re-dispatched, retried and speculated
//! chunks all land in the same ascending-chunk fold, so a run with
//! faults is bit-identical to the fault-free elastic run and to
//! `threads --sched steal`.

pub mod loopback;
pub mod wire;
pub mod worker;

pub use loopback::{LoopbackCluster, WorkerDrill};
pub use worker::{SessionFault, ShardWorker};
