//! Adversarial property tests for the serve protocol front ends.
//!
//! The load-bearing contract: the SIMD tape parser
//! ([`parakmeans::serve::scan`]) must be answer-equivalent to the
//! legacy byte-wise parser ([`parakmeans::util::json::Json::parse`]) on
//! *every* input, on *every* kernel tier — same accept set, identical
//! values on accepted documents, a typed error (never a panic) on
//! everything else. The suites below push generated-valid, mutated,
//! truncated, random-soup and non-UTF-8 inputs through both parsers and
//! through the [`ClientRequest`] extraction on top of them; well over
//! 5,000 adversarial inputs execute per `cargo test` run.
//!
//! `PARAKM_KERNEL=scalar` pins everything to the scalar tier (the CI
//! bit-identity job runs this file that way); unpinned runs also cover
//! the host's best SIMD tier via [`kernel::detect`].

use parakmeans::linalg::kernel::{self, KernelTier};
use parakmeans::serve::scan;
use parakmeans::serve::{ClientRequest, Response};
use parakmeans::testutil::prop::{self, Gen, Outcome};
use parakmeans::util::json::Json;

/// Scalar always; the host's SIMD tier too when it has one.
fn tiers() -> Vec<KernelTier> {
    let mut t = vec![KernelTier::Scalar];
    let best = kernel::detect();
    if best != KernelTier::Scalar {
        t.push(best);
    }
    t
}

/// The equivalence oracle: both parsers agree on ok-ness, and on
/// accepted documents they produce identical values. Error prose may
/// differ between the two (both still reject), so it is not compared.
fn assert_equivalent(input: &str, tier: KernelTier) -> Outcome {
    let legacy = Json::parse(input);
    let tape = scan::parse_tape_tier(input, tier);
    match (&legacy, &tape) {
        (Ok(a), Ok(b)) => prop::ensure(
            a == b,
            format!("tier {tier}: values diverge on {input:?}: legacy={a:?} tape={b:?}"),
        ),
        (Ok(a), Err(e)) => Err(format!(
            "tier {tier}: tape rejected a document legacy accepts: {input:?} (legacy={a:?}, tape \
             err={e})"
        )),
        (Err(e), Ok(b)) => Err(format!(
            "tier {tier}: tape accepted a document legacy rejects: {input:?} (tape={b:?}, legacy \
             err={e})"
        )),
        (Err(_), Err(_)) => Ok(()),
    }
    .and_then(|()| {
        // and the request extraction on top agrees too
        let l = ClientRequest::parse(input);
        let t = ClientRequest::parse_tape_tier(input, tier);
        prop::ensure(
            l.is_ok() == t.is_ok() && l.ok() == t.ok(),
            format!("tier {tier}: ClientRequest front ends diverge on {input:?}"),
        )
    })
}

/// A structurally valid request line with deliberate variety:
/// whitespace placement, number formats, key order, escapes in extra
/// string fields, nested extra objects.
fn gen_valid_line(g: &mut Gen) -> String {
    let ws = ["", " ", "  ", "\t", " \t "];
    let id = g.usize_in(0, 1 << 40);
    let npoints = g.usize_in(1, 6);
    let dim = g.usize_in(1, 5);
    let mut points = Vec::new();
    for _ in 0..npoints {
        let coords: Vec<String> = (0..dim)
            .map(|_| match g.usize_in(0, 4) {
                0 => format!("{}", g.usize_in(0, 999)),
                1 => format!("-{}", g.usize_in(0, 999)),
                2 => format!("{:.3}", g.f64_in(-1e3, 1e3)),
                3 => format!("{}e{}", g.usize_in(1, 99), g.usize_in(0, 5)),
                _ => format!("{:.6}E-{}", g.f64_in(0.0, 9.0), g.usize_in(0, 4)),
            })
            .collect();
        points.push(format!("[{}{}{}]", g.choice(&ws), coords.join(", "), g.choice(&ws)));
    }
    let id_field = format!(r#""id"{}:{}{id}"#, g.choice(&ws), g.choice(&ws));
    let points_field = format!(r#""points": [{}]"#, points.join(", "));
    let mut fields = vec![id_field, points_field];
    if g.bool() {
        // extra fields with escape-rich strings exercise the scanner's
        // quote pairing and the parser's slow path
        let extras = [
            r#""tag": "a\"b\\c\nAé""#,
            r#""meta": {"nested": [1, {"x": null}], "ok": true}"#,
            r#""note": "plain ascii text""#,
            r#""unicode": "héllo wörld 😀""#,
        ];
        fields.push((*g.choice(&extras)).to_string());
    }
    if g.bool() {
        // key order must not matter
        fields.reverse();
    }
    format!("{}{{{}}}{}", g.choice(&ws), fields.join(", "), g.choice(&ws))
}

#[test]
fn valid_lines_parse_identically_on_every_tier() {
    let tiers = tiers();
    prop::check("tape ≡ legacy on generated valid lines", 1200, |g| {
        let line = gen_valid_line(g);
        for &tier in &tiers {
            assert_equivalent(&line, tier)?;
            // a generated-valid line must actually be accepted
            prop::ensure(
                ClientRequest::parse_tape_tier(&line, tier).is_ok(),
                format!("tier {tier}: generated line rejected: {line:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn mutated_lines_never_panic_and_stay_equivalent() {
    let tiers = tiers();
    prop::check("tape ≡ legacy under mutation", 1500, |g| {
        let mut bytes = gen_valid_line(g).into_bytes();
        let edits = g.usize_in(1, 8);
        g.mutate(&mut bytes, edits);
        // non-UTF-8 mutants never reach the parsers in the serve path
        // (the loops answer ERR_NOT_UTF8 first); parity holds on the
        // rest
        if let Ok(s) = std::str::from_utf8(&bytes) {
            for &tier in &tiers {
                assert_equivalent(s, tier)?;
            }
        }
        Ok(())
    });
}

#[test]
fn random_json_soup_is_rejected_identically() {
    let tiers = tiers();
    // heavy on structural bytes: reaches deep parser states that
    // uniform random bytes almost never do
    let alphabet = br#"{}[],:"\ 0123456789.eE+-truefalsnu"#;
    prop::check("tape ≡ legacy on JSON soup", 1000, |g| {
        let n = g.usize_in(0, 120);
        let soup = g.ascii_soup(n, alphabet);
        let s = std::str::from_utf8(&soup).expect("alphabet is ascii");
        for &tier in &tiers {
            assert_equivalent(s, tier)?;
        }
        Ok(())
    });
}

#[test]
fn every_truncation_of_valid_lines_is_equivalent() {
    let tiers = tiers();
    let mut g = Gen::new(0x7a93);
    let mut cases = 0u64;
    for _ in 0..12 {
        let line = gen_valid_line(&mut g);
        for cut in 0..=line.len() {
            if !line.is_char_boundary(cut) {
                continue;
            }
            let prefix = &line[..cut];
            for &tier in &tiers {
                if let Err(m) = assert_equivalent(prefix, tier) {
                    panic!("truncation at {cut} of {line:?}: {m}");
                }
                cases += 1;
            }
        }
    }
    assert!(cases >= 500, "expected a dense truncation sweep, got {cases}");
}

#[test]
fn deep_nesting_is_a_typed_error_on_both_paths() {
    let tiers = tiers();
    for depth in [10, 127, 128, 129, 1_000, 50_000] {
        let doc = format!("{}1{}", "[".repeat(depth), "]".repeat(depth));
        for &tier in &tiers {
            let legacy = Json::parse(&doc);
            let tape = scan::parse_tape_tier(&doc, tier);
            assert_eq!(legacy.is_ok(), tape.is_ok(), "tier {tier}: depth {depth} ok-ness diverges");
            if legacy.is_ok() {
                assert_eq!(legacy.unwrap(), tape.unwrap(), "tier {tier}: depth {depth}");
            }
        }
    }
}

#[test]
fn non_utf8_bytes_never_panic_the_byte_level_entry() {
    prop::check("non-utf8 soup is survivable", 800, |g| {
        let n = g.usize_in(0, 100);
        let bytes = g.bytes(n);
        // the serve loops gate on from_utf8 before parsing — replicate
        // that exact path: invalid sequences are a typed rejection,
        // valid ones must keep the two parsers in agreement
        match std::str::from_utf8(&bytes) {
            Err(_) => Ok(()), // the loop answers ERR_NOT_UTF8; nothing to parse
            Ok(s) => assert_equivalent(s, KernelTier::Scalar),
        }
    });
}

#[test]
fn structural_offsets_agree_across_tiers() {
    let tiers = tiers();
    if tiers.len() < 2 {
        eprintln!("host has no SIMD tier; scalar-only run");
    }
    prop::check("structural offsets scalar ≡ simd", 600, |g| {
        // byte lengths straddling every SIMD block boundary
        let n = g.usize_in(0, 140);
        let bytes = if g.bool() {
            g.bytes(n)
        } else {
            g.ascii_soup(n, br#"{}[],:"\xyz "#)
        };
        let want = scan::structural_offsets(&bytes, KernelTier::Scalar);
        for &tier in &tiers {
            let got = scan::structural_offsets(&bytes, tier);
            prop::ensure(
                got == want,
                format!("tier {tier}: offsets diverge on {bytes:?}: {got:?} vs {want:?}"),
            )?;
        }
        Ok(())
    });
}

#[test]
fn response_lines_roundtrip() {
    prop::check("response to_line/parse roundtrip", 600, |g| {
        let resp = if g.bool() {
            let n = g.usize_in(0, 8);
            Response::Ok {
                id: g.usize_in(0, 1 << 40) as u64,
                clusters: (0..n).map(|_| g.usize_in(0, 64) as i32).collect(),
                distances: (0..n).map(|_| g.f32_in(0.0, 1e6)).collect(),
            }
        } else {
            Response::Err {
                id: g.usize_in(0, 1 << 40) as u64,
                error: format!("error #{} with \"quotes\" and \\slashes", g.usize_in(0, 99)),
            }
        };
        let line = resp.to_line();
        let back = Response::parse(&line)
            .map_err(|e| format!("roundtrip parse failed on {line:?}: {e}"))?;
        prop::ensure(back == resp, format!("roundtrip diverged: {resp:?} → {line:?} → {back:?}"))
    });
}
