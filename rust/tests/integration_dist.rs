//! Integration: the distributed engine end-to-end over loopback TCP —
//! the DESIGN.md §10 contracts in executable form.
//!
//! Bit-identity: `dist(S)` must reproduce `threads(p = S)` and
//! `oocore(shards = S)` bit-for-bit (assignments, centroid bits, SSE
//! bits, iteration history) for S ∈ {1, 2, 4} on the paper's 2D and 3D
//! GMM families, regardless of worker reply timing. CI runs this suite
//! again with `PARAKM_KERNEL=scalar` forced, so tier dispatch cannot
//! hide a divergence.
//!
//! Fault injection, static scheduler: a worker dropping mid-iteration,
//! a truncated frame, and a wrong-dimension shard must each surface the
//! matching typed [`Error::Cluster`] variant promptly — the leader
//! fails fast, never hangs.
//!
//! Fault injection, elastic scheduler (DESIGN.md §12): a worker killed
//! mid-iteration, a worker stalled past the net timeout, and a worker
//! that rejoins mid-run must each leave the run *completing*,
//! bit-identical to the fault-free elastic run and to
//! `threads --sched steal`, with the recovery visible in `NetStats`.

use std::net::TcpListener;
use std::time::{Duration, Instant};

use parakmeans::cluster::wire::{self, Frame, WIRE_VERSION};
use parakmeans::cluster::{LoopbackCluster, SessionFault, ShardWorker, WorkerDrill};
use parakmeans::config::{DistSched, SchedMode};
use parakmeans::data::source::{ChunkReader, DataSource, MemorySource, OwnedMemorySource};
use parakmeans::data::{Dataset, MixtureSpec};
use parakmeans::error::ClusterError;
use parakmeans::kmeans::dist::{self, DistOpts, DistRun};
use parakmeans::kmeans::streaming::{self, StreamOpts};
use parakmeans::kmeans::{init, parallel, serial, KmeansConfig};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::Error;

fn opts() -> DistOpts {
    DistOpts {
        connect_timeout: Duration::from_secs(5),
        io_timeout: Duration::from_secs(5),
        ..Default::default()
    }
}

/// The acceptance matrix: dist(S) ≡ threads(p=S) ≡ oocore(shards=S),
/// bit for bit, on one paper dataset.
fn check_identity_matrix(ds: &Dataset, k: usize, what: &str) {
    let cfg = KmeansConfig::new(k).with_seed(7);
    let mu0 = init::initialize(ds, k, cfg.init, cfg.seed);
    for s in [1usize, 2, 4] {
        let cluster = LoopbackCluster::spawn_dataset(ds, s, 257).unwrap();
        let run = dist::run_from(&cluster.addrs, &cfg, &opts(), &mu0).unwrap();
        cluster.join().unwrap();

        let threads = parallel::run_from(ds, &cfg, s, parallel::MergeMode::Leader, &mu0);
        assert_bit_identical(&run.result, &threads, &format!("{what}: dist({s}) vs threads"));

        let src = MemorySource::new(ds);
        let oocore =
            streaming::run_from(&src, &cfg, &StreamOpts { shards: s, chunk_rows: 401 }, &mu0)
                .unwrap();
        assert_bit_identical(&run.result, &oocore, &format!("{what}: dist({s}) vs oocore"));

        // telemetry is aligned with the iteration history
        assert_eq!(run.net.per_iter.len(), run.result.iterations, "{what}: telemetry");
        assert_eq!(run.net.workers, s, "{what}: worker count");
    }
}

#[test]
fn dist_bit_identical_to_threads_and_oocore_paper_2d() {
    let ds = parakmeans::eval::paper_dataset(2, 4003);
    check_identity_matrix(&ds, 8, "paper 2D");
}

#[test]
fn dist_bit_identical_to_threads_and_oocore_paper_3d() {
    let ds = parakmeans::eval::paper_dataset(3, 3001);
    check_identity_matrix(&ds, 4, "paper 3D");
}

#[test]
fn full_run_with_init_matches_serial() {
    // dist::run (leader-side gather init) == serial::run (resident
    // init): identical index sampling makes the whole pipelines
    // coincide, exactly as for the out-of-core engine
    let ds = parakmeans::eval::paper_dataset(3, 1500);
    let cfg = KmeansConfig::new(4).with_seed(21);
    let reference = serial::run(&ds, &cfg);
    let cluster = LoopbackCluster::spawn_dataset(&ds, 1, 128).unwrap();
    let run = dist::run(&cluster.addrs, &cfg, &opts()).unwrap();
    cluster.join().unwrap();
    assert_bit_identical(&run.result, &reference, "dist::run vs serial::run");
}

// ---- reply-order independence ------------------------------------------

/// A [`DataSource`] that delays every reader open — making its worker
/// reliably the *last* to reply each iteration.
struct SlowSource {
    inner: OwnedMemorySource,
    delay: Duration,
}

impl DataSource for SlowSource {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn len(&self) -> usize {
        self.inner.len()
    }

    fn reader(
        &self,
        lo: usize,
        hi: usize,
        chunk_rows: usize,
    ) -> parakmeans::Result<Box<dyn ChunkReader + '_>> {
        std::thread::sleep(self.delay);
        self.inner.reader(lo, hi, chunk_rows)
    }

    fn describe(&self) -> String {
        format!("slow({})", self.inner.describe())
    }
}

#[test]
fn reply_arrival_order_cannot_change_results() {
    // shard 0 is artificially the slowest: replies arrive 1, 2, 0 every
    // iteration, yet the fold is by shard index — results must equal
    // the undelayed run bit-for-bit
    let ds = parakmeans::eval::paper_dataset(2, 1803);
    let cfg = KmeansConfig::new(8).with_seed(5).with_max_iters(12);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);

    let baseline_cluster = LoopbackCluster::spawn_dataset(&ds, 3, 256).unwrap();
    let baseline = dist::run_from(&baseline_cluster.addrs, &cfg, &opts(), &mu0).unwrap();
    baseline_cluster.join().unwrap();

    let ranges = parakmeans::data::dataset::shard_ranges(ds.len(), 3);
    let mut workers = Vec::new();
    for (i, &(lo, hi)) in ranges.iter().enumerate() {
        let shard = Dataset::from_vec(ds.rows(lo, hi).to_vec(), ds.dim()).unwrap();
        let inner = OwnedMemorySource::new(shard);
        let src: Box<dyn DataSource + Send + Sync> = if i == 0 {
            Box::new(SlowSource { inner, delay: Duration::from_millis(10) })
        } else {
            Box::new(inner)
        };
        workers.push(ShardWorker::new(src, 256).unwrap());
    }
    let cluster = LoopbackCluster::spawn(workers).unwrap();
    let delayed = dist::run_from(&cluster.addrs, &cfg, &opts(), &mu0).unwrap();
    cluster.join().unwrap();

    assert_bit_identical(&delayed.result, &baseline.result, "delayed shard 0 vs baseline");
}

// ---- fault injection ----------------------------------------------------

/// Short timeouts so every fault must surface fast.
fn fault_opts() -> DistOpts {
    DistOpts {
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_secs(2),
        ..Default::default()
    }
}

/// A hand-rolled fake worker: answers the handshake like a real shard,
/// then misbehaves per `script` on the first `Assign`.
fn fake_worker(rows: u64, dim: u32, script: FaultScript) -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (mut stream, _) = listener.accept().unwrap();
        // handshake: Hello -> ShardSpec
        match wire::read_frame(&mut stream, "hello").unwrap().0 {
            Frame::Hello { version } => assert_eq!(version, WIRE_VERSION),
            other => panic!("fake worker: unexpected {other:?}"),
        }
        wire::write_frame(&mut stream, &Frame::ShardSpec { rows, dim }).unwrap();
        // wait for the first Assign, then misbehave
        let _ = wire::read_frame(&mut stream, "assign");
        match script {
            FaultScript::DropConnection => drop(stream),
            FaultScript::TruncatedFrame => {
                use std::io::Write as _;
                // declare a 1000-byte Partials frame, send 10 bytes,
                // vanish
                let mut bytes = Vec::new();
                bytes.extend_from_slice(&1000u32.to_le_bytes());
                bytes.push(4); // Partials type byte
                bytes.extend_from_slice(&[0u8; 9]);
                stream.write_all(&bytes).unwrap();
                stream.flush().unwrap();
                drop(stream);
            }
            FaultScript::GarbageFrame => {
                use std::io::Write as _;
                // well-formed length, unknown type byte
                stream.write_all(&[2u8, 0, 0, 0, 0xEE, 0x00]).unwrap();
                stream.flush().unwrap();
                // keep the socket open: the error must come from the
                // frame decoder, not a disconnect
                std::thread::sleep(Duration::from_secs(4));
            }
            FaultScript::SilentStall => {
                // never reply: the leader's read timeout must fire
                std::thread::sleep(Duration::from_secs(8));
            }
        }
    });
    addr
}

#[derive(Clone, Copy)]
enum FaultScript {
    DropConnection,
    TruncatedFrame,
    GarbageFrame,
    SilentStall,
}

/// One healthy loopback worker + one scripted fake, shard order
/// [healthy, fake]; returns the leader's error and how long it took.
fn run_against_fault(script: FaultScript) -> (Error, Duration) {
    let ds = MixtureSpec::paper_2d(4).generate(600, 3);
    let half = Dataset::from_vec(ds.rows(0, 300).to_vec(), 2).unwrap();
    let healthy = ShardWorker::new(Box::new(OwnedMemorySource::new(half)), 128).unwrap();
    let cluster = LoopbackCluster::spawn(vec![healthy]).unwrap();
    let fake = fake_worker(300, 2, script);
    let addrs = vec![cluster.addrs[0].clone(), fake];

    let cfg = KmeansConfig::new(4).with_seed(1);
    let mu0: Vec<f32> = ds.rows(0, 4).to_vec();
    let t0 = Instant::now();
    let err = dist::run_from(&addrs, &cfg, &fault_opts(), &mu0).unwrap_err();
    let elapsed = t0.elapsed();
    // the leader dropped its connections: the healthy worker ends its
    // session at the boundary instead of hanging
    cluster.join().unwrap();
    (err, elapsed)
}

#[test]
fn worker_drop_mid_iteration_is_prompt_connection_error() {
    let (err, elapsed) = run_against_fault(FaultScript::DropConnection);
    assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");
    assert!(elapsed < Duration::from_secs(10), "leader stalled {elapsed:?}");
}

#[test]
fn truncated_frame_is_prompt_frame_error() {
    let (err, elapsed) = run_against_fault(FaultScript::TruncatedFrame);
    assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
    assert!(err.to_string().contains("truncated"), "{err}");
    assert!(elapsed < Duration::from_secs(10), "leader stalled {elapsed:?}");
}

#[test]
fn garbage_frame_type_is_prompt_frame_error() {
    let (err, elapsed) = run_against_fault(FaultScript::GarbageFrame);
    assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err}");
    assert!(err.to_string().contains("unknown frame type"), "{err}");
    assert!(elapsed < Duration::from_secs(10), "leader stalled {elapsed:?}");
}

#[test]
fn silent_worker_hits_the_read_timeout_not_a_hang() {
    let (err, elapsed) = run_against_fault(FaultScript::SilentStall);
    assert!(matches!(err, Error::Cluster(ClusterError::Connection(_))), "{err}");
    assert!(err.to_string().contains("timed out"), "{err}");
    // io_timeout is 2s; well under the fake's 8s stall proves the
    // timeout fired rather than the worker finally hanging up
    assert!(elapsed < Duration::from_secs(6), "leader stalled {elapsed:?}");
}

// ---- elastic fault matrix (DESIGN.md §12) -------------------------------

fn elastic_opts() -> DistOpts {
    DistOpts {
        connect_timeout: Duration::from_secs(2),
        io_timeout: Duration::from_secs(2),
        sched: DistSched::Elastic,
        retry: 2,
    }
}

/// Run the elastic leader against replicated drilled workers and also
/// compute the two references every drill must reproduce bit-for-bit:
/// the fault-free elastic run and the in-memory work-stealing engine.
fn elastic_drill(
    ds: &Dataset,
    cfg: &KmeansConfig,
    opts: &DistOpts,
    drills: &[WorkerDrill],
) -> DistRun {
    let mu0 = init::initialize(ds, cfg.k, cfg.init, cfg.seed);

    let clean_cluster = LoopbackCluster::spawn_replicated(ds, drills.len(), 256).unwrap();
    let clean = dist::run_from(&clean_cluster.addrs, cfg, opts, &mu0).unwrap();
    clean_cluster.join().unwrap();

    let cluster = LoopbackCluster::spawn_replicated_faulty(ds, 256, drills).unwrap();
    let faulty = dist::run_from(&cluster.addrs, cfg, opts, &mu0).unwrap();
    cluster.join().unwrap();

    assert_bit_identical(&faulty.result, &clean.result, "elastic faulty vs fault-free");
    let steal = parallel::run_from_sched(
        ds,
        cfg,
        drills.len(),
        parallel::MergeMode::Leader,
        SchedMode::Steal,
        &mu0,
    );
    assert_bit_identical(&faulty.result, &steal, "elastic faulty vs threads-steal");
    assert_eq!(faulty.net.per_iter.len(), faulty.result.iterations);
    faulty
}

#[test]
fn elastic_survives_a_worker_killed_mid_iteration() {
    // worker 0 dies on its second chunk — mid-iteration, holding an
    // unanswered claim while most of the iteration is still unclaimed —
    // and never comes back (one session only); the other two workers
    // absorb its chunks
    let ds = MixtureSpec::paper_2d(8).generate(30_000, 17);
    let cfg = KmeansConfig::new(8).with_seed(5).with_max_iters(8);
    let drills = [
        WorkerDrill {
            fault: SessionFault { die_after_chunks: Some(1), ..Default::default() },
            sessions: 1,
        },
        WorkerDrill::default(),
        WorkerDrill::default(),
    ];
    let run = elastic_drill(&ds, &cfg, &elastic_opts(), &drills);
    assert!(run.net.worker_failures >= 1, "{:?}", run.net);
    // the dying worker held an unanswered chunk: it must have been
    // returned to the queue and re-dispatched
    assert!(run.net.redispatched_chunks >= 1, "{:?}", run.net);
}

#[test]
fn elastic_outruns_a_worker_stalled_past_the_net_timeout() {
    // worker 0 answers one chunk, then sleeps 3 s on every subsequent
    // request — past the 1 s io timeout. Its in-flight chunk is rescued
    // either by a speculative re-execution winning or by the timeout
    // returning it to the queue; both paths must be visible
    let ds = MixtureSpec::paper_2d(8).generate(12_000, 23);
    let cfg = KmeansConfig::new(8).with_seed(9).with_max_iters(5);
    let opts = DistOpts { io_timeout: Duration::from_secs(1), retry: 1, ..elastic_opts() };
    let drills = [
        WorkerDrill {
            fault: SessionFault {
                stall_after_chunks: Some((1, Duration::from_secs(3))),
                ..Default::default()
            },
            sessions: 1,
        },
        WorkerDrill::default(),
        WorkerDrill::default(),
    ];
    let run = elastic_drill(&ds, &cfg, &opts, &drills);
    // the stalled read is guaranteed to time out eventually
    assert!(run.net.worker_failures >= 1, "{:?}", run.net);
    assert!(
        run.net.speculative_wins + run.net.redispatched_chunks >= 1,
        "straggler neither outrun nor re-dispatched: {:?}",
        run.net
    );
}

#[test]
fn elastic_readmits_a_worker_rejoining_mid_run() {
    // worker 0 crashes after one chunk but serves a second session: the
    // leader must reconnect it with a Rejoin handshake and use it
    // again. Worker 1 is merely slow (30 ms per chunk, well under the
    // timeout) so there is always work left when worker 0 comes back
    let ds = MixtureSpec::paper_2d(8).generate(20_000, 31);
    let cfg = KmeansConfig::new(8).with_seed(3).with_max_iters(4);
    let drills = [
        WorkerDrill {
            fault: SessionFault { die_after_chunks: Some(1), ..Default::default() },
            sessions: 2,
        },
        WorkerDrill {
            fault: SessionFault {
                stall_after_chunks: Some((0, Duration::from_millis(30))),
                ..Default::default()
            },
            sessions: 1,
        },
    ];
    let run = elastic_drill(&ds, &cfg, &elastic_opts(), &drills);
    assert!(run.net.worker_failures >= 1, "{:?}", run.net);
    assert!(run.net.worker_rejoins >= 1, "no Rejoin handshake: {:?}", run.net);
}

#[test]
fn wrong_dimension_shard_fails_the_handshake() {
    // shard 0 is 2D, shard 1 is 3D: the leader must reject the cluster
    // before any iteration runs
    let d2 = MixtureSpec::paper_2d(4).generate(200, 1);
    let d3 = MixtureSpec::paper_3d(4).generate(200, 1);
    let w2 = ShardWorker::new(Box::new(OwnedMemorySource::new(d2)), 64).unwrap();
    let w3 = ShardWorker::new(Box::new(OwnedMemorySource::new(d3)), 64).unwrap();
    let cluster = LoopbackCluster::spawn(vec![w2, w3]).unwrap();

    let cfg = KmeansConfig::new(2).with_seed(1);
    let t0 = Instant::now();
    let err = dist::run(&cluster.addrs, &cfg, &fault_opts()).unwrap_err();
    assert!(matches!(err, Error::Cluster(ClusterError::Shape(_))), "{err}");
    assert!(err.to_string().contains("disagree"), "{err}");
    assert!(t0.elapsed() < Duration::from_secs(5));
    cluster.join().unwrap();
}
