//! Integration: the parallel pruned engines' determinism contract
//! (DESIGN.md §9) on the paper's GMM datasets.
//!
//! Pins the PR's acceptance criteria: `elkan`/`hamerly` with
//! `--threads p` are **bit-identical** to their single-worker runs for
//! p ∈ {1, 2, 4} and both `--sched` modes; both track serial Lloyd's
//! label trajectory exactly (they are exact accelerations); and the
//! dense threaded engine's steal mode is bit-identical across worker
//! counts. Run with `PARAKM_KERNEL=scalar` in CI so a SIMD-tier
//! divergence cannot hide behind dispatch.

use parakmeans::config::SchedMode;
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::kmeans::{self, elkan, hamerly, parallel, KmeansConfig};
use parakmeans::testutil::assert_bit_identical;

const THREADS: [usize; 3] = [1, 2, 4];
const MODES: [SchedMode; 2] = [SchedMode::Static, SchedMode::Steal];

fn paper_cases() -> Vec<(&'static str, parakmeans::data::Dataset, usize)> {
    vec![
        // ragged sizes: the tail chunk is shorter than CHUNK_ROWS and
        // the tail block shorter than POINTS_BLOCK
        ("2d", MixtureSpec::paper_2d(8).generate(20_003, 42), 8),
        ("3d", MixtureSpec::paper_3d(4).generate(15_001, 7), 4),
    ]
}

#[test]
fn elkan_threads_bit_identical_and_tracks_lloyd() {
    for (name, ds, k) in paper_cases() {
        let cfg = KmeansConfig::new(k).with_seed(5);
        let mu0 = kmeans::init::initialize(&ds, k, cfg.init, cfg.seed);
        let lloyd = kmeans::serial::run_from(&ds, &cfg, &mu0);
        let one = elkan::run_from_threads(&ds, &cfg, 1, SchedMode::Steal, &mu0);

        // exact acceleration: the label trajectory is serial Lloyd's
        assert_eq!(one.assign, lloyd.assign, "{name}: elkan vs lloyd labels");
        assert_eq!(one.iterations, lloyd.iterations, "{name}: iteration trajectory");
        assert!(
            (one.sse - lloyd.sse).abs() / lloyd.sse.max(1.0) < 1e-6,
            "{name}: sse {} vs {}",
            one.sse,
            lloyd.sse
        );
        for (a, b) in one.centroids.iter().zip(&lloyd.centroids) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{name}: centroid {a} vs {b}");
        }

        for p in THREADS {
            for mode in MODES {
                let r = elkan::run_from_threads(&ds, &cfg, p, mode, &mu0);
                assert_bit_identical(&r, &one, &format!("{name}: elkan p={p} {mode}"));
                assert_eq!(r.pruning, one.pruning, "{name}: elkan p={p} {mode} counters");
            }
        }
    }
}

#[test]
fn hamerly_threads_bit_identical_and_tracks_lloyd() {
    for (name, ds, k) in paper_cases() {
        let cfg = KmeansConfig::new(k).with_seed(5);
        let mu0 = kmeans::init::initialize(&ds, k, cfg.init, cfg.seed);
        let lloyd = kmeans::serial::run_from(&ds, &cfg, &mu0);
        let one = hamerly::run_from_threads(&ds, &cfg, 1, SchedMode::Steal, &mu0);

        assert_eq!(one.assign, lloyd.assign, "{name}: hamerly vs lloyd labels");
        assert_eq!(one.iterations, lloyd.iterations, "{name}: iteration trajectory");
        for (a, b) in one.centroids.iter().zip(&lloyd.centroids) {
            assert!((a - b).abs() <= 1e-4 * a.abs().max(1.0), "{name}: centroid {a} vs {b}");
        }

        for p in THREADS {
            for mode in MODES {
                let r = hamerly::run_from_threads(&ds, &cfg, p, mode, &mu0);
                assert_bit_identical(&r, &one, &format!("{name}: hamerly p={p} {mode}"));
                assert_eq!(r.pruning, one.pruning, "{name}: hamerly p={p} {mode} counters");
            }
        }
    }
}

#[test]
fn elkan_and_hamerly_agree_exactly() {
    for (name, ds, k) in paper_cases() {
        let cfg = KmeansConfig::new(k).with_seed(5);
        let mu0 = kmeans::init::initialize(&ds, k, cfg.init, cfg.seed);
        let elk = elkan::run_from_threads(&ds, &cfg, 4, SchedMode::Steal, &mu0);
        let ham = hamerly::run_from_threads(&ds, &cfg, 4, SchedMode::Steal, &mu0);
        assert_eq!(elk.assign, ham.assign, "{name}: elkan vs hamerly labels");
        assert_eq!(elk.iterations, ham.iterations, "{name}");
        // Elkan's k bounds prune harder than Hamerly's one
        let (es, hs) = (elk.pruning.unwrap(), ham.pruning.unwrap());
        assert!(es.skip_rate() > 0.0, "{name}: elkan skipped nothing");
        assert!(hs.skip_rate() > 0.0, "{name}: hamerly skipped nothing");
    }
}

#[test]
fn dense_threads_steal_mode_bit_identical_across_p() {
    let ds = MixtureSpec::paper_3d(4).generate(15_001, 7);
    let cfg = KmeansConfig::new(4).with_seed(5);
    let mu0 = kmeans::init::initialize(&ds, 4, cfg.init, cfg.seed);
    let one = parallel::run_from_sched(
        &ds,
        &cfg,
        1,
        parallel::MergeMode::Leader,
        SchedMode::Steal,
        &mu0,
    );
    let stat = parallel::run_from(&ds, &cfg, 4, parallel::MergeMode::Leader, &mu0);
    assert_eq!(one.assign, stat.assign, "steal vs static assignments");
    assert_eq!(one.iterations, stat.iterations);
    for p in [2usize, 4, 8] {
        let r = parallel::run_from_sched(
            &ds,
            &cfg,
            p,
            parallel::MergeMode::Leader,
            SchedMode::Steal,
            &mu0,
        );
        assert_bit_identical(&r, &one, &format!("threads steal p={p}"));
    }
}

#[test]
fn pruned_engines_report_skip_rate_through_run() {
    // the KmeansResult surface (what the CLI prints and the bench CSV
    // records): counters present, aligned with history, rates sane
    let ds = MixtureSpec::paper_2d(8).generate(10_000, 3);
    let cfg = KmeansConfig::new(8).with_seed(9);
    for (name, r) in [
        ("elkan", elkan::run_threads(&ds, &cfg, 2, SchedMode::Steal)),
        ("hamerly", hamerly::run_threads(&ds, &cfg, 2, SchedMode::Steal)),
    ] {
        let prune = r.pruning.as_ref().unwrap_or_else(|| panic!("{name}: no counters"));
        assert_eq!(prune.seed_computed, 10_000 * 8, "{name}");
        assert_eq!(prune.per_iter.len(), r.iterations, "{name}");
        let rate = prune.skip_rate();
        assert!((0.0..=1.0).contains(&rate), "{name}: rate {rate}");
        assert!(rate > 0.3, "{name}: paper GMMs should prune well, got {rate}");
    }
    // dense engines report none
    let dense = kmeans::serial::run(&ds, &cfg);
    assert!(dense.pruning.is_none());
}
