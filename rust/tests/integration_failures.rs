//! Failure injection: corrupted artifacts, bad manifests, and hostile
//! inputs must surface as clean errors — never panics, hangs or wrong
//! results.

use std::path::{Path, PathBuf};

use parakmeans::config::RunConfig;
use parakmeans::coordinator::offload;
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::runtime::Runtime;

fn artifacts_ready() -> bool {
    Path::new("artifacts/manifest.json").exists()
}

/// Copy the real artifacts dir so tests can vandalize it safely.
fn cloned_artifacts(name: &str) -> PathBuf {
    let dst = std::env::temp_dir().join("parakm_failure_tests").join(name);
    let _ = std::fs::remove_dir_all(&dst);
    std::fs::create_dir_all(&dst).unwrap();
    for entry in std::fs::read_dir("artifacts").unwrap() {
        let entry = entry.unwrap();
        std::fs::copy(entry.path(), dst.join(entry.file_name())).unwrap();
    }
    dst
}

#[test]
fn missing_artifacts_dir_is_clean_error() {
    let missing = std::env::temp_dir().join("parakm_no_such_artifacts");
    let _ = std::fs::remove_dir_all(&missing);
    match Runtime::new(&missing) {
        Err(parakmeans::Error::Manifest(msg)) => {
            assert!(msg.contains("make artifacts"), "{msg}");
        }
        Err(other) => panic!("expected manifest error, got {other}"),
        Ok(_) => panic!("expected manifest error, got a runtime"),
    }
}

#[test]
fn corrupt_manifest_json_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = cloned_artifacts("bad_json");
    std::fs::write(dir.join("manifest.json"), "{ not json !!!").unwrap();
    assert!(Runtime::new(&dir).is_err());
}

#[test]
fn manifest_referencing_missing_file_fails_at_prepare() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = cloned_artifacts("missing_hlo");
    // remove one HLO file the manifest still references
    std::fs::remove_file(dir.join("finalize_d3_k4.hlo.txt")).unwrap();
    let ds = MixtureSpec::paper_3d(4).generate(5000, 1);
    let cfg = RunConfig { k: 4, artifacts_dir: dir, ..Default::default() };
    let err = offload::run(&ds, &cfg).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("finalize") || msg.to_lowercase().contains("no such file"), "{msg}");
}

#[test]
fn truncated_hlo_text_fails_to_compile_cleanly() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = cloned_artifacts("truncated_hlo");
    let victim = dir.join("stats_partial_d3_k4_c4096.hlo.txt");
    let text = std::fs::read_to_string(&victim).unwrap();
    std::fs::write(&victim, &text[..text.len() / 3]).unwrap();
    let ds = MixtureSpec::paper_3d(4).generate(3000, 1);
    let cfg = RunConfig { k: 4, chunk: 4096, artifacts_dir: dir, ..Default::default() };
    // shared engine prepares stats_partial first — must error, not crash
    let res = parakmeans::coordinator::shared::run(&ds, &cfg, 2);
    assert!(res.is_err(), "corrupted HLO must not compile");
}

#[test]
fn garbage_hlo_body_is_clean_error() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dir = cloned_artifacts("garbage_hlo");
    // the native executor validates artifact *structure* only (module
    // header, ENTRY/ROOT, balanced braces) — semantically-invalid ops
    // in a well-formed module are a real-PJRT-compile concern, so the
    // garbage here is structural
    std::fs::write(
        dir.join("fused_stats_d3_k4_c4096.hlo.txt"),
        "HloModule junk\n\nENTRY main { ROOT x = f32[] wat(",
    )
    .unwrap();
    let ds = MixtureSpec::paper_3d(4).generate(3000, 1);
    let cfg = RunConfig { k: 4, chunk: 4096, artifacts_dir: dir, ..Default::default() };
    assert!(offload::run(&ds, &cfg).is_err());
}

#[test]
fn zero_k_rejected_before_runtime_touched() {
    let ds = MixtureSpec::paper_3d(4).generate(100, 1);
    let cfg = RunConfig { k: 0, ..Default::default() };
    assert!(offload::run(&ds, &cfg).is_err());
}

#[test]
fn empty_dataset_rejected() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = parakmeans::data::Dataset::from_vec(vec![], 3).unwrap();
    let cfg = RunConfig { k: 4, ..Default::default() };
    assert!(offload::run(&ds, &cfg).is_err());
}
