//! Cross-policy integration suite (DESIGN.md §11): with the default
//! `exact` policy every documented bit-identity contract is untouched
//! (pinned by the other integration suites); this file pins what the
//! `dot` policy promises instead —
//!
//! - identical assignments and iteration counts to `exact` on the
//!   paper 2D/3D GMM suites, SSE relative error < 1e-5, for every
//!   pure-rust engine (serial, threads both sched modes, oocore, dist
//!   over loopback workers, minibatch);
//! - the *within-policy* determinism contracts survive: oocore(S, dot)
//!   ≡ threads(p = S, dot) bit-for-bit, and chunk size / worker count
//!   never change dot results.
//!
//! CI also runs the whole file with `PARAKM_KERNEL=scalar` forced, so
//! the contracts hold on the reference tier itself.

use parakmeans::cluster::LoopbackCluster;
use parakmeans::config::{DistancePolicy, SchedMode};
use parakmeans::data::{MemorySource, MixtureSpec};
use parakmeans::kmeans::streaming::{self, StreamOpts};
use parakmeans::kmeans::{
    dist, elkan, hamerly, init, minibatch, parallel, serial, KmeansConfig, KmeansResult,
};

/// The cross-policy agreement the acceptance criteria state: same
/// clustering trajectory, SSE within tolerance.
fn assert_policy_agrees(dot: &KmeansResult, exact: &KmeansResult, what: &str) {
    assert_eq!(dot.assign, exact.assign, "{what}: assignments");
    assert_eq!(dot.iterations, exact.iterations, "{what}: iterations");
    assert_eq!(dot.converged, exact.converged, "{what}: converged");
    let rel = (dot.sse - exact.sse).abs() / exact.sse.max(1.0);
    assert!(rel < 1e-5, "{what}: sse relative error {rel}");
}

fn paper(dim: usize, n: usize, seed: u64) -> (parakmeans::data::Dataset, KmeansConfig) {
    let (spec, k) = match dim {
        2 => (MixtureSpec::paper_2d(8), 8),
        _ => (MixtureSpec::paper_3d(4), 4),
    };
    (spec.generate(n, seed), KmeansConfig::new(k).with_seed(5))
}

#[test]
fn serial_dot_matches_exact_paper_2d_and_3d() {
    for dim in [2usize, 3] {
        let (ds, cfg) = paper(dim, 6003, 11);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let exact = serial::run_from(&ds, &cfg, &mu0);
        let dot = serial::run_from(&ds, &cfg.clone().with_distance(DistancePolicy::Dot), &mu0);
        assert_policy_agrees(&dot, &exact, &format!("serial paper {dim}D"));
    }
}

#[test]
fn threads_dot_matches_exact_both_sched_modes() {
    let (ds, cfg) = paper(2, 5003, 3);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    let exact = serial::run_from(&ds, &cfg, &mu0);
    let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
    for p in [1usize, 2, 4] {
        for sched in [SchedMode::Static, SchedMode::Steal] {
            let dot = parallel::run_from_sched(
                &ds,
                &dcfg,
                p,
                parallel::MergeMode::Leader,
                sched,
                &mu0,
            );
            assert_policy_agrees(&dot, &exact, &format!("threads p={p} {sched:?}"));
        }
    }
}

#[test]
fn oocore_dot_bit_identical_to_threads_dot_and_chunk_blind() {
    let (ds, cfg) = paper(3, 3001, 7);
    let dcfg = cfg.with_distance(DistancePolicy::Dot);
    let mu0 = init::initialize(&ds, dcfg.k, dcfg.init, dcfg.seed);
    let src = MemorySource::new(&ds);
    for p in [1usize, 2, 4] {
        let threads =
            parallel::run_from(&ds, &dcfg, p, parallel::MergeMode::Leader, &mu0);
        let mut baseline: Option<KmeansResult> = None;
        for chunk in [64usize, 500, 100_000] {
            let run = streaming::run_from(
                &src,
                &dcfg,
                &StreamOpts { shards: p, chunk_rows: chunk },
                &mu0,
            )
            .unwrap();
            parakmeans::testutil::assert_bit_identical(
                &run,
                &threads,
                &format!("oocore(dot) S={p} chunk={chunk} vs threads"),
            );
            if let Some(b) = &baseline {
                parakmeans::testutil::assert_bit_identical(
                    &run,
                    b,
                    &format!("oocore(dot) chunk={chunk} vs first chunk size"),
                );
            } else {
                baseline = Some(run);
            }
        }
    }
}

#[test]
fn dist_dot_over_loopback_matches_exact_and_oocore() {
    let (ds, cfg) = paper(2, 2401, 9);
    let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
    let mu0 = init::initialize(&ds, dcfg.k, dcfg.init, dcfg.seed);
    let exact = serial::run_from(&ds, &cfg, &mu0);

    for shards in [1usize, 3] {
        let cluster = LoopbackCluster::spawn_dataset(&ds, shards, 200).unwrap();
        let run = dist::run_from(
            &cluster.addrs,
            &dcfg,
            &dist::DistOpts::default(),
            &mu0,
        )
        .unwrap();
        cluster.join().unwrap();
        assert_policy_agrees(&run.result, &exact, &format!("dist(dot) S={shards}"));

        // and bit-identity with the out-of-core engine at equal shards
        let oocore = streaming::run_from(
            &MemorySource::new(&ds),
            &dcfg,
            &StreamOpts { shards, chunk_rows: 200 },
            &mu0,
        )
        .unwrap();
        parakmeans::testutil::assert_bit_identical(
            &run.result,
            &oocore,
            &format!("dist(dot) S={shards} vs oocore"),
        );
    }
}

#[test]
fn pruned_engines_dot_match_exact_lloyd_clustering() {
    let (ds, cfg) = paper(3, 4001, 13);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    let lloyd = serial::run_from(&ds, &cfg, &mu0);
    let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
    for p in [1usize, 4] {
        let elk = elkan::run_from_threads(&ds, &dcfg, p, SchedMode::Steal, &mu0);
        assert_eq!(elk.iterations, lloyd.iterations, "elkan dot p={p}");
        let ari = parakmeans::metrics::adjusted_rand_index(&elk.assign, &lloyd.assign);
        assert!(ari > 0.9999, "elkan dot p={p}: ari {ari}");
        assert!((elk.sse - lloyd.sse).abs() / lloyd.sse < 1e-5, "elkan dot p={p}");

        let ham = hamerly::run_from_threads(&ds, &dcfg, p, SchedMode::Steal, &mu0);
        assert_eq!(ham.iterations, lloyd.iterations, "hamerly dot p={p}");
        let ari = parakmeans::metrics::adjusted_rand_index(&ham.assign, &lloyd.assign);
        assert!(ari > 0.9999, "hamerly dot p={p}: ari {ari}");
        assert!((ham.sse - lloyd.sse).abs() / lloyd.sse < 1e-5, "hamerly dot p={p}");
    }
}

#[test]
fn minibatch_dot_matches_exact() {
    let (ds, cfg) = paper(2, 8000, 17);
    let exact = minibatch::run(&ds, &cfg, 1024);
    let dot = minibatch::run(&ds, &cfg.clone().with_distance(DistancePolicy::Dot), 1024);
    assert_policy_agrees(&dot, &exact, "minibatch");
}
