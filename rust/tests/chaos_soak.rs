//! Chaos soak (DESIGN.md §16): sweep seeded fault injections across
//! every chaos site and assert the system-wide robustness invariant —
//! an injected fault yields a **typed error** or a **bit-identical
//! result**, never a panic, a hang, or silently corrupt output.
//!
//! Injection budget across the suite (the acceptance floor is 1,000
//! injections over at least 5 sites):
//!
//! - `artifact_read_sweep`: 170 seeds × 4 reads = **680** exact
//!   (`artifact-read`, period 1 — every hooked read fires).
//! - `artifact_write_sweep`: 170 seeds × 3 writes = **510** exact
//!   (`atomic-write`, period 1) over `.pkm` / `.pkd` / `.pkc` payloads
//!   — the torn-write matrix.
//! - `engine_ckpt_chaos`: serial / threads / oocore under mixed
//!   `atomic-write` + `artifact-read` faults, with chaos-armed and
//!   chaos-off resume legs.
//! - `dist_wire_chaos`: static + elastic leaders over loopback TCP
//!   under `wire-read` / `wire-write` faults, driven until both sites
//!   fire repeatedly.
//! - `serve_chaos`: both serve loops under `serve-accept` /
//!   `serve-enqueue` / `batcher` faults, driven until all three sites
//!   fire, then proven to recover to answering cleanly.
//!
//! Totals: ≥ 1,190 deterministic injections plus the driven legs,
//! spanning all 7 sites. Every test serializes on
//! [`chaos::test_lock`] because the plan registry is process-global.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::time::Duration;

use parakmeans::cluster::LoopbackCluster;
use parakmeans::config::{DistSched, SchedMode};
use parakmeans::data::source::MemorySource;
use parakmeans::data::{io, MixtureSpec};
use parakmeans::error::{Error, Result};
use parakmeans::kmeans::ckpt::{self, CkptSink};
use parakmeans::kmeans::dist::{self, DistOpts};
use parakmeans::kmeans::streaming::{self, StreamOpts};
use parakmeans::kmeans::{parallel, serial, KmeansConfig, KmeansResult};
use parakmeans::serve::{serve, Response, ServeConfig, ServeLoop};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::util::chaos::{self, ChaosPlan, Site};

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("parakm_chaos_soak_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Per-leg accumulator over plan reinstalls ([`chaos::fired_by_site`]
/// resets on every install, so legs absorb before uninstalling).
#[derive(Default)]
struct Tally {
    by_site: BTreeMap<&'static str, u64>,
}

impl Tally {
    fn absorb(&mut self) {
        for (site, n) in chaos::fired_by_site() {
            *self.by_site.entry(site).or_insert(0) += n;
        }
    }

    fn of(&self, site: &str) -> u64 {
        self.by_site.get(site).copied().unwrap_or(0)
    }
}

fn sample_model() -> io::Model {
    io::Model {
        k: 4,
        dim: 3,
        seed: 7,
        engine: "serial".into(),
        iterations: 5,
        sse: 12.5,
        centroids: (0..12).map(|i| i as f32 * 0.5 - 3.0).collect(),
    }
}

/// Build a checkpoint directory with both A/B slots intact (chaos off)
/// and return it with its fingerprint.
fn seeded_ckpt_dir(tag: &str) -> (PathBuf, ckpt::Fingerprint) {
    let ds = MixtureSpec::paper_2d(4).generate(401, 19);
    let cfg = KmeansConfig::new(4).with_seed(13).with_tol(0.0).with_max_iters(4);
    let fp = ckpt::fingerprint("serial", "none", &cfg, ds.len(), ds.dim());
    let dir = tmp(tag);
    let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
    serial::run_ckpt(&ds, &cfg, Some(&sink), None).unwrap();
    (dir, fp)
}

// ---- artifact sweeps: the deterministic bulk of the budget -------------

/// 170 seeds × (1 `.pkm` read + 1 `.pkd` read + 2 `.pkc` slot reads),
/// period 1: exactly 680 injections. Every faulted read must surface a
/// typed error or decode to content equal to what was written (the
/// legal outcome when a truncation lands exactly on the optional CRC
/// trailer boundary of the legacy-tolerant formats). The files on disk
/// are never mutated by a read fault: after the sweep every artifact
/// still round-trips bit-exactly.
#[test]
fn artifact_read_sweep_typed_error_or_identical() {
    let _g = chaos::test_lock();
    let fired0 = chaos::fired_total();

    let dir = tmp("read_sweep");
    let model = sample_model();
    let pkm = dir.join("m.pkm");
    io::write_model(&pkm, &model).unwrap();
    let ds = MixtureSpec::paper_2d(4).generate(300, 5);
    let pkd = dir.join("d.pkd");
    io::write_binary(&pkd, &ds).unwrap();
    let (ckdir, fp) = seeded_ckpt_dir("read_sweep_ck");
    let base_state = ckpt::load_validated(&ckdir, &fp).unwrap();

    let mut tally = Tally::default();
    for seed in 0..170u64 {
        chaos::install(&ChaosPlan::new(seed).with_sites(&[Site::ArtifactRead]).with_period(1));
        match io::read_model(&pkm) {
            Ok(m) => assert_eq!(m, model, "seed {seed}: faulted .pkm read must stay exact"),
            Err(e) => {
                let _ = e.to_string(); // typed, renderable, no panic
            }
        }
        match io::read_binary(&pkd) {
            Ok(d) => assert_eq!(d.raw(), ds.raw(), "seed {seed}: faulted .pkd read"),
            Err(e) => {
                let _ = e.to_string();
            }
        }
        match ckpt::load(&ckdir) {
            // a surviving load may legitimately be the older A/B slot
            Ok(s) => {
                assert!(
                    s.iteration >= 1 && s.iteration <= base_state.iteration,
                    "seed {seed}: .pkc iteration {}",
                    s.iteration
                );
                if s.iteration == base_state.iteration {
                    assert_eq!(s.centroids, base_state.centroids, "seed {seed}: .pkc centroids");
                }
            }
            Err(e) => {
                let _ = e.to_string();
            }
        }
        tally.absorb();
        chaos::uninstall();
    }

    // read faults only ever touch in-memory copies: the artifacts on
    // disk still round-trip exactly
    assert_eq!(io::read_model(&pkm).unwrap(), model);
    assert_eq!(io::read_binary(&pkd).unwrap().raw(), ds.raw());
    assert_eq!(ckpt::load_validated(&ckdir, &fp).unwrap().centroids, base_state.centroids);

    let fired = chaos::fired_total() - fired0;
    assert_eq!(fired, 170 * 4, "period-1 sweep must fire on every hooked read");
    assert_eq!(tally.of("artifact-read"), 170 * 4);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ckdir);
}

/// The torn-write matrix (satellite of DESIGN.md §16): 170 seeds × 3
/// atomic publishes (`.pkm`, `.pkd`, `.pkc` payloads), period 1 —
/// exactly 510 injections. An injected `Fail` must leave no
/// destination file and a `"chaos: injected"` typed error; a torn or
/// bit-flipped publish may land, but then the reader must either
/// reject it (CRC) or decode content equal to the original.
#[test]
fn artifact_write_sweep_torn_publishes_never_corrupt() {
    let _g = chaos::test_lock();
    let fired0 = chaos::fired_total();

    let dir = tmp("write_sweep");
    let model = sample_model();
    let ds = MixtureSpec::paper_2d(4).generate(300, 5);
    let src_pkd = dir.join("src.pkd");
    io::write_binary(&src_pkd, &ds).unwrap();
    let pkd_bytes = std::fs::read(&src_pkd).unwrap();
    let (ckdir, _fp) = seeded_ckpt_dir("write_sweep_ck");
    let pkc_bytes = std::fs::read(ckdir.join(ckpt::SLOT_A)).unwrap();
    let base_state = io::decode_ckpt(&pkc_bytes).unwrap();

    let mut tally = Tally::default();
    for seed in 0..170u64 {
        chaos::install(&ChaosPlan::new(seed).with_sites(&[Site::AtomicWrite]).with_period(1));

        let pkm = dir.join(format!("w_{seed}.pkm"));
        match io::write_model(&pkm, &model) {
            Err(e) => {
                assert!(e.to_string().contains("chaos: injected"), "seed {seed}: {e}");
                assert!(!pkm.exists(), "seed {seed}: failed write must not publish");
            }
            Ok(()) => {
                // period 1: an Ok write means the payload was published
                // torn or bit-flipped — the reader must catch it or
                // (trailer-boundary truncation) decode the exact model
                if let Ok(m) = io::read_model(&pkm) {
                    assert_eq!(m, model, "seed {seed}: survivor .pkm must be exact");
                }
            }
        }

        let pkd = dir.join(format!("w_{seed}.pkd"));
        match io::atomic_write(&pkd, &pkd_bytes) {
            Err(e) => {
                assert!(e.to_string().contains("chaos: injected"), "seed {seed}: {e}");
                assert!(!pkd.exists(), "seed {seed}: failed write must not publish");
            }
            Ok(()) => {
                if let Ok(d) = io::read_binary(&pkd) {
                    assert_eq!(d.raw(), ds.raw(), "seed {seed}: survivor .pkd must be exact");
                }
            }
        }

        let pkc = dir.join(format!("w_{seed}.pkc"));
        match io::atomic_write(&pkc, &pkc_bytes) {
            Err(e) => {
                assert!(e.to_string().contains("chaos: injected"), "seed {seed}: {e}");
                assert!(!pkc.exists(), "seed {seed}: failed write must not publish");
            }
            Ok(()) => {
                if let Ok(s) = io::decode_ckpt(&std::fs::read(&pkc).unwrap()) {
                    assert_eq!(s.centroids, base_state.centroids, "seed {seed}: survivor .pkc");
                }
            }
        }

        tally.absorb();
        chaos::uninstall();
    }

    let fired = chaos::fired_total() - fired0;
    assert_eq!(fired, 170 * 3, "period-1 sweep must fire on every atomic publish");
    assert_eq!(tally.of("atomic-write"), 170 * 3);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ckdir);
}

// ---- engines under checkpoint chaos ------------------------------------

type CkptEngine<'a> =
    &'a dyn Fn(&KmeansConfig, Option<&CkptSink>, Option<ckpt::CkptState>) -> Result<KmeansResult>;

/// One engine under mixed artifact chaos: the chaos-armed run is
/// bit-identical or typed-failed; a chaos-armed resume from whatever
/// slots survived is bit-identical or typed-failed; and a chaos-OFF
/// resume from any loadable slot is *always* bit-identical — the A/B
/// rotation + CRC guarantee chaos cannot corrupt recovery.
fn engine_chaos_leg(tag: &str, fp_engine: &str, fp_sched: &str, run: CkptEngine<'_>) {
    let n = 1001;
    let d = 2;
    let cfg = KmeansConfig::new(4).with_seed(11).with_tol(0.0).with_max_iters(6);
    let fp = ckpt::fingerprint(fp_engine, fp_sched, &cfg, n, d);
    let base = run(&cfg, None, None).unwrap();
    assert_eq!(base.iterations, 6, "{tag}: tol 0 runs the full budget");

    let mut tally = Tally::default();
    for seed in 0..10u64 {
        let dir = tmp(&format!("engine_{tag}_{seed}"));
        let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
        chaos::install(
            &ChaosPlan::new(seed)
                .with_sites(&[Site::AtomicWrite, Site::ArtifactRead])
                .with_period(2),
        );
        match run(&cfg, Some(&sink), None) {
            Ok(r) => assert_bit_identical(&r, &base, &format!("{tag} seed {seed}: chaos run")),
            Err(e) => {
                let _ = e.to_string(); // typed ckpt-write failure
            }
        }
        // chaos-armed resume: slot reads themselves may fault
        match ckpt::load_validated(&dir, &fp) {
            Ok(state) => match run(&cfg, None, Some(state)) {
                Ok(r) => {
                    assert_bit_identical(&r, &base, &format!("{tag} seed {seed}: chaos resume"))
                }
                Err(e) => {
                    let _ = e.to_string();
                }
            },
            Err(e) => {
                let _ = e.to_string();
            }
        }
        tally.absorb();
        chaos::uninstall();

        // chaos off: if anything is loadable, recovery must be exact
        if let Ok(state) = ckpt::load_validated(&dir, &fp) {
            let r = run(&cfg, None, Some(state)).unwrap();
            assert_bit_identical(&r, &base, &format!("{tag} seed {seed}: clean resume"));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
    assert!(
        tally.of("atomic-write") + tally.of("artifact-read") >= 10,
        "{tag}: the sweep must actually inject ({:?})",
        tally.by_site
    );
}

#[test]
fn serial_with_ckpt_chaos_bit_identical_or_typed() {
    let _g = chaos::test_lock();
    let ds = MixtureSpec::paper_2d(4).generate(1001, 9);
    engine_chaos_leg("serial", "serial", "none", &|cfg, sink, resume| {
        serial::run_ckpt(&ds, cfg, sink, resume)
    });
}

#[test]
fn threads_with_ckpt_chaos_bit_identical_or_typed() {
    let _g = chaos::test_lock();
    let ds = MixtureSpec::paper_2d(4).generate(1001, 9);
    engine_chaos_leg("threads", "threads", "static", &|cfg, sink, resume| {
        parallel::run_sched_ckpt(
            &ds,
            cfg,
            3,
            parallel::MergeMode::Leader,
            SchedMode::Static,
            sink,
            resume,
        )
    });
}

#[test]
fn oocore_with_ckpt_chaos_bit_identical_or_typed() {
    let _g = chaos::test_lock();
    let ds = MixtureSpec::paper_2d(4).generate(1001, 9);
    let opts = StreamOpts { shards: 3, chunk_rows: 127 };
    engine_chaos_leg("oocore", "oocore", "static", &|cfg, sink, resume| {
        streaming::run_ckpt(&MemorySource::new(&ds), cfg, &opts, sink, resume)
    });
}

// ---- distributed leaders under wire chaos ------------------------------

/// Static and elastic leaders over loopback TCP with `wire-read` /
/// `wire-write` faults (both leader- and worker-side — the plan is
/// process-global). Static must fail fast and typed; elastic may also
/// recover to the bit-identical result. Driven until both wire sites
/// have fired at least 5 times each.
#[test]
fn dist_wire_chaos_typed_error_or_identical() {
    let _g = chaos::test_lock();
    let ds = MixtureSpec::paper_2d(4).generate(601, 3);
    let cfg = KmeansConfig::new(4).with_seed(5).with_tol(0.0).with_max_iters(4);
    let opts = |sched| DistOpts {
        connect_timeout: Duration::from_secs(5),
        io_timeout: Duration::from_secs(2),
        sched,
        retry: 1,
    };

    let cluster = LoopbackCluster::spawn_dataset(&ds, 2, 128).unwrap();
    let base_static = dist::run(&cluster.addrs, &cfg, &opts(DistSched::Static)).unwrap();
    cluster.join().unwrap();
    let cluster = LoopbackCluster::spawn_replicated(&ds, 2, 128).unwrap();
    let base_elastic = dist::run(&cluster.addrs, &cfg, &opts(DistSched::Elastic)).unwrap();
    cluster.join().unwrap();

    let mut tally = Tally::default();
    for seed in 0..30u64 {
        let cluster = LoopbackCluster::spawn_dataset(&ds, 2, 128).unwrap();
        chaos::install(
            &ChaosPlan::new(seed)
                .with_sites(&[Site::WireRead, Site::WireWrite])
                .with_period(4),
        );
        let out = dist::run(&cluster.addrs, &cfg, &opts(DistSched::Static));
        tally.absorb();
        chaos::uninstall();
        let _ = cluster.join(); // worker-side injections surface here; fine
        match out {
            Ok(run) => {
                assert_bit_identical(&run.result, &base_static.result, &format!("static {seed}"))
            }
            Err(e) => assert!(matches!(e, Error::Cluster(_)), "static seed {seed}: {e}"),
        }
        if tally.of("wire-read") >= 5 && tally.of("wire-write") >= 5 {
            break;
        }
    }
    assert!(
        tally.of("wire-read") >= 5 && tally.of("wire-write") >= 5,
        "wire sites never fired enough: {:?}",
        tally.by_site
    );

    // elastic: chunk re-dispatch may outrun the injected faults — a
    // completed run must be bit-identical, a failed one typed
    for seed in 100..103u64 {
        let cluster = LoopbackCluster::spawn_replicated(&ds, 2, 128).unwrap();
        chaos::install(
            &ChaosPlan::new(seed)
                .with_sites(&[Site::WireRead, Site::WireWrite])
                .with_period(6),
        );
        let out = dist::run(&cluster.addrs, &cfg, &opts(DistSched::Elastic));
        chaos::uninstall();
        let _ = cluster.join();
        match out {
            Ok(run) => assert_bit_identical(
                &run.result,
                &base_elastic.result,
                &format!("elastic {seed}"),
            ),
            Err(e) => assert!(matches!(e, Error::Cluster(_)), "elastic seed {seed}: {e}"),
        }
    }
}

// ---- serve loops under accept / enqueue / batcher chaos ----------------

enum Outcome {
    Answered,
    TypedError,
    Dropped,
}

fn try_request(addr: std::net::SocketAddr, id: u64) -> Outcome {
    let Ok(mut conn) = TcpStream::connect(addr) else {
        return Outcome::Dropped;
    };
    conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    if writeln!(conn, r#"{{"id": {id}, "points": [[0.5, 0.5, 0.5]]}}"#).is_err() {
        return Outcome::Dropped;
    }
    let mut reader = BufReader::new(conn);
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) | Err(_) => Outcome::Dropped, // accept-chaos drop / reset
        Ok(_) => match Response::parse(&line) {
            Ok(Response::Ok { id: rid, clusters, .. }) => {
                assert_eq!(rid, id, "response id echo");
                assert_eq!(clusters.len(), 1);
                Outcome::Answered
            }
            Ok(Response::Err { .. }) => Outcome::TypedError, // ERR_RETRY etc.
            other => panic!("unparseable serve reply {other:?}: {line:?}"),
        },
    }
}

/// Both serve loops under dropped accepts, swallowed enqueues and
/// injected batcher panics: every request resolves (answer, typed
/// error line, or visibly dropped connection — never a hang), and once
/// chaos stops the server must return to answering, with the batcher
/// restarts it survived visible in the stats.
#[test]
fn serve_chaos_drops_typed_never_hangs_and_recovers() {
    let _g = chaos::test_lock();
    let modes: Vec<ServeLoop> = if cfg!(unix) {
        vec![ServeLoop::Threads, ServeLoop::Poll]
    } else {
        vec![ServeLoop::Threads]
    };
    let ds = MixtureSpec::paper_3d(4).generate(500, 3);
    let model = serial::run(&ds, &KmeansConfig::new(4).with_seed(1));

    for (mi, mode) in modes.into_iter().enumerate() {
        let cfg = ServeConfig {
            addr: "127.0.0.1:0".into(),
            // never-existing artifacts dir: the batcher falls back to
            // the in-crate native runtime
            artifacts_dir: std::env::temp_dir().join("parakm_chaos_soak/no_artifacts_here"),
            loop_mode: mode,
            ..Default::default()
        };
        let server = serve(cfg, model.centroids.clone(), 3, 4).unwrap();

        chaos::install(
            &ChaosPlan::new(0xC0FFEE + mi as u64)
                .with_sites(&[Site::ServeAccept, Site::ServeEnqueue, Site::Batcher])
                .with_period(5),
        );
        let mut answered = 0u64;
        let mut typed = 0u64;
        let mut dropped = 0u64;
        let mut covered = false;
        for i in 0..400u64 {
            match try_request(server.local_addr, i) {
                Outcome::Answered => answered += 1,
                Outcome::TypedError => typed += 1,
                Outcome::Dropped => dropped += 1,
            }
            let fired = chaos::fired_by_site();
            let of = |s: &str| fired.get(s).copied().unwrap_or(0);
            if i >= 40 && of("serve-accept") >= 5 && of("serve-enqueue") >= 5 && of("batcher") >= 2
            {
                covered = true;
                break;
            }
        }
        let mut tally = Tally::default();
        tally.absorb();
        chaos::uninstall();
        assert!(
            covered,
            "mode {mode}: chaos sites never fired enough \
             (answered {answered}, typed {typed}, dropped {dropped}, {:?})",
            tally.by_site
        );

        // chaos off: the server must recover to answering (the batcher
        // may still be inside its restart backoff — retry through it)
        let mut recovered = false;
        for i in 0..60u64 {
            if matches!(try_request(server.local_addr, 10_000 + i), Outcome::Answered) {
                recovered = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(250));
        }
        assert!(recovered, "mode {mode}: server did not recover after chaos stopped");

        let stats = server.stats();
        if tally.of("batcher") >= 1 {
            assert!(
                stats.batcher_restarts >= 1,
                "mode {mode}: {} injected batcher panics but no restart recorded",
                tally.of("batcher")
            );
            assert!(
                stats.batcher_last_restart.contains("chaos: injected"),
                "mode {mode}: restart reason {:?}",
                stats.batcher_last_restart
            );
        }
        assert_eq!(stats.model_generation, 1, "mode {mode}: chaos must not touch the model");
        server.shutdown();
    }
}
