//! Integration: the `parakm` binary end-to-end — gen-data → run →
//! assign-out round trip, info, and error paths. Exercises the CLI
//! parser, dataset IO and engine plumbing the way a user would.

use std::path::PathBuf;
use std::process::Command;

fn parakm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_parakm"))
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("parakm_cli_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn no_args_prints_usage() {
    let out = parakm().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("usage: parakm"));
}

#[test]
fn unknown_subcommand_fails() {
    let out = parakm().arg("explode").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn gen_data_then_run_serial() {
    let data = tmp("cli_d3.pkd");
    let out = parakm()
        .args(["gen-data", "--dim", "3", "--n", "5000", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let assign = tmp("cli_assign.csv");
    let out = parakm()
        .args(["run", "--engine", "serial", "--k", "4", "--input"])
        .arg(&data)
        .arg("--assign-out")
        .arg(&assign)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged: true"), "{text}");
    assert!(text.contains("ARI vs truth"), "{text}");
    // assignment file has 5000 rows + header
    let lines = std::fs::read_to_string(&assign).unwrap().lines().count();
    assert_eq!(lines, 5001);
}

#[test]
fn run_synthetic_offload() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = parakm()
        .args([
            "run", "--synthetic", "3d:8000", "--engine", "offload", "--k", "4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("engine      : offload"), "{text}");
    assert!(text.contains("iter loop"), "{text}");
}

#[test]
fn run_rejects_bad_flags() {
    // typo'd flag
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "serial", "--k", "4", "--wat", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag"));
    // bad engine
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "gpu", "--k", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    // missing k
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "serial"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn info_lists_artifacts() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = parakm().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stats_partial_d3_k4"), "{text}");
    assert!(text.contains("assign_d3_k4"), "{text}");
    assert!(text.contains("finalize_d2_k11"), "{text}");
}

#[test]
fn gen_data_csv_roundtrip() {
    let data = tmp("cli_d2.csv");
    let out = parakm()
        .args(["gen-data", "--dim", "2", "--n", "300", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success());
    let out = parakm()
        .args(["run", "--engine", "hamerly", "--k", "4", "--input"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("300 points, 2D"));
}
