//! Integration: the `parakm` binary end-to-end — gen-data → run →
//! assign-out round trip, info, and error paths. Exercises the CLI
//! parser, dataset IO and engine plumbing the way a user would.

use std::path::PathBuf;
use std::process::Command;

fn parakm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_parakm"))
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("parakm_cli_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

#[test]
fn no_args_prints_usage() {
    let out = parakm().output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("usage: parakm"));
}

#[test]
fn unknown_subcommand_fails() {
    let out = parakm().arg("explode").output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown subcommand"));
}

#[test]
fn gen_data_then_run_serial() {
    let data = tmp("cli_d3.pkd");
    let out = parakm()
        .args(["gen-data", "--dim", "3", "--n", "5000", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let assign = tmp("cli_assign.csv");
    let out = parakm()
        .args(["run", "--engine", "serial", "--k", "4", "--input"])
        .arg(&data)
        .arg("--assign-out")
        .arg(&assign)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("converged: true"), "{text}");
    assert!(text.contains("ARI vs truth"), "{text}");
    // assignment file has 5000 rows + header
    let lines = std::fs::read_to_string(&assign).unwrap().lines().count();
    assert_eq!(lines, 5001);
}

#[test]
fn run_synthetic_offload() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = parakm()
        .args([
            "run", "--synthetic", "3d:8000", "--engine", "offload", "--k", "4",
        ])
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("engine      : offload"), "{text}");
    assert!(text.contains("iter loop"), "{text}");
}

#[test]
fn run_distance_dot_matches_exact_at_the_cli() {
    let data = tmp("cli_dp.pkd");
    let out = parakm()
        .args(["gen-data", "--dim", "3", "--n", "4000", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    let run = |policy: &str, csv: &PathBuf| {
        let out = parakm()
            .args(["run", "--engine", "serial", "--k", "4", "--seed", "42", "--distance", policy])
            .arg("--input")
            .arg(&data)
            .arg("--assign-out")
            .arg(csv)
            .output()
            .unwrap();
        assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
        String::from_utf8_lossy(&out.stdout).into_owned()
    };
    let exact_csv = tmp("cli_dp_exact.csv");
    let dot_csv = tmp("cli_dp_dot.csv");
    let exact_text = run("exact", &exact_csv);
    let dot_text = run("dot", &dot_csv);
    assert!(exact_text.contains("distance    : exact"), "{exact_text}");
    assert!(dot_text.contains("distance    : dot"), "{dot_text}");
    // the DESIGN.md §11 cross-policy contract, end to end: identical
    // assignment CSVs and iteration counts
    assert_eq!(
        std::fs::read_to_string(&exact_csv).unwrap(),
        std::fs::read_to_string(&dot_csv).unwrap()
    );
    let iters = |t: &str| {
        t.lines().find(|l| l.starts_with("iterations")).map(str::to_string)
    };
    assert_eq!(iters(&exact_text), iters(&dot_text));

    // AOT engines reject the dot policy; bad values are typed errors
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "offload", "--k", "4"])
        .args(["--distance", "dot"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("pure-rust"));
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "serial", "--k", "4"])
        .args(["--distance", "cosine"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown distance policy"));
}

#[test]
fn run_rejects_bad_flags() {
    // typo'd flag
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "serial", "--k", "4", "--wat", "1"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown flag"));
    // bad engine
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "gpu", "--k", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    // missing k
    let out = parakm()
        .args(["run", "--synthetic", "3d:1000", "--engine", "serial"])
        .output()
        .unwrap();
    assert!(!out.status.success());
}

#[test]
fn info_lists_artifacts() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let out = parakm().arg("info").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("stats_partial_d3_k4"), "{text}");
    assert!(text.contains("assign_d3_k4"), "{text}");
    assert!(text.contains("finalize_d2_k11"), "{text}");
}

#[test]
fn gen_data_csv_roundtrip() {
    let data = tmp("cli_d2.csv");
    let out = parakm()
        .args(["gen-data", "--dim", "2", "--n", "300", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success());
    let out = parakm()
        .args(["run", "--engine", "hamerly", "--k", "4", "--input"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("300 points, 2D"));
}

#[test]
fn save_model_roundtrips_byte_exact() {
    let model_path = tmp("cli_model.pkm");
    let out = parakm()
        .args([
            "run", "--synthetic", "3d:2000", "--engine", "serial", "--k", "4", "--seed", "7",
            "--save-model",
        ])
        .arg(&model_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("model       :"));

    let model = parakmeans::data::io::read_model(&model_path).unwrap();
    assert_eq!((model.k, model.dim, model.seed), (4, 3, 7));
    assert_eq!(model.engine, "serial");
    assert!(model.iterations > 0);

    // the persisted centroids are bit-exact against retraining in-process
    let ds = parakmeans::eval::paper_dataset(3, 2000);
    let retrained = parakmeans::kmeans::serial::run(
        &ds,
        &parakmeans::kmeans::KmeansConfig::new(4).with_seed(7),
    );
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&model.centroids), bits(&retrained.centroids));
    assert_eq!(model.sse.to_bits(), retrained.sse.to_bits());
}

#[test]
fn serve_loads_model_and_answers_stats_probe() {
    use std::io::{BufRead, BufReader, Write};

    // train + persist
    let model_path = tmp("cli_serve_model.pkm");
    let out = parakm()
        .args([
            "run", "--synthetic", "3d:2000", "--engine", "serial", "--k", "4", "--save-model",
        ])
        .arg(&model_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // serve from the model (no --input, no retraining); artifacts dir
    // that never exists forces the native runtime fallback
    let mut child = parakm()
        .args(["serve", "--model"])
        .arg(&model_path)
        .args(["--addr", "127.0.0.1:0", "--artifacts"])
        .arg(tmp("no_artifacts_here"))
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::null())
        .spawn()
        .unwrap();

    // "serving on <addr>" is println!'d (line-buffered) once ready
    let mut stdout = BufReader::new(child.stdout.take().unwrap());
    let mut line = String::new();
    stdout.read_line(&mut line).unwrap();
    let result = (|| -> Result<(), String> {
        let addr = line
            .strip_prefix("serving on ")
            .and_then(|r| r.split_whitespace().next())
            .ok_or_else(|| format!("unexpected serve banner: {line}"))?;

        let mut conn = std::net::TcpStream::connect(addr).map_err(|e| e.to_string())?;
        conn.set_read_timeout(Some(std::time::Duration::from_secs(20))).unwrap();
        let mut reader = BufReader::new(conn.try_clone().unwrap());
        let mut reply = String::new();

        // assignment served straight from the loaded model
        writeln!(conn, r#"{{"id": 9, "points": [[0.0, 0.0, 0.0]]}}"#).map_err(|e| e.to_string())?;
        reader.read_line(&mut reply).map_err(|e| e.to_string())?;
        if !reply.contains("\"clusters\"") {
            return Err(format!("expected clusters reply, got: {reply}"));
        }

        // the observability probe
        writeln!(conn, r#"{{"stats": true}}"#).map_err(|e| e.to_string())?;
        reply.clear();
        reader.read_line(&mut reply).map_err(|e| e.to_string())?;
        for key in ["\"requests\"", "\"points\"", "\"batches\"", "\"padded_rows\"", "\"saturated\""]
        {
            if !reply.contains(key) {
                return Err(format!("stats line missing {key}: {reply}"));
            }
        }
        Ok(())
    })();
    let _ = child.kill();
    let _ = child.wait();
    result.unwrap();
}

#[test]
fn worker_and_dist_leader_roundtrip_via_cli() {
    use std::io::{BufRead, BufReader};

    let data = tmp("cli_dist.pkd");
    let out = parakm()
        .args(["gen-data", "--dim", "2", "--n", "3000", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));

    // two worker processes, each owning half the file, ephemeral ports
    let mut spawn_worker = |shard: &str| {
        let mut child = parakm()
            .args(["worker", "--listen", "127.0.0.1:0", "--input"])
            .arg(&data)
            .args(["--shard", shard, "--once"])
            .stdout(std::process::Stdio::piped())
            .stderr(std::process::Stdio::null())
            .spawn()
            .unwrap();
        let mut stdout = BufReader::new(child.stdout.take().unwrap());
        let mut line = String::new();
        stdout.read_line(&mut line).unwrap();
        let addr = line
            .strip_prefix("worker listening on ")
            .and_then(|r| r.split_whitespace().next())
            .unwrap_or_else(|| panic!("unexpected worker banner: {line}"))
            .to_string();
        (child, addr)
    };
    let (mut w0, addr0) = spawn_worker("0/2");
    let (mut w1, addr1) = spawn_worker("1/2");

    let dist_assign = tmp("cli_dist_assign.csv");
    let out = parakm()
        .args(["run", "--engine", "dist", "--workers"])
        .arg(format!("{addr0},{addr1}"))
        .args(["--k", "4", "--seed", "42", "--assign-out"])
        .arg(&dist_assign)
        .output()
        .unwrap();
    let _ = w0.wait();
    let _ = w1.wait();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("engine      : dist"), "{text}");
    assert!(text.contains("workers     : 2"), "{text}");
    assert!(text.contains("wire        :"), "{text}");

    // bit-identity at the CLI level: same assignment CSV as threads p=2
    let threads_assign = tmp("cli_threads_assign.csv");
    let out = parakm()
        .args([
            "run", "--engine", "threads", "--threads", "2", "--sched", "static", "--k", "4",
            "--seed", "42", "--input",
        ])
        .arg(&data)
        .arg("--assign-out")
        .arg(&threads_assign)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert_eq!(
        std::fs::read(&dist_assign).unwrap(),
        std::fs::read(&threads_assign).unwrap(),
        "dist and threads assignment files differ"
    );
}

#[test]
fn dist_leader_rejects_missing_workers_flag() {
    let out = parakm()
        .args(["run", "--engine", "dist", "--k", "4"])
        .output()
        .unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("--workers"));
}
