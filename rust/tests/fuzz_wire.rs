//! Fuzz layer for the length-prefixed binary wire protocol
//! ([`parakmeans::cluster::wire`]).
//!
//! The frame decoder faces the network, so its contract is absolute:
//! any byte stream — random soup, bit-flipped valid frames, truncations
//! at every boundary, forged length prefixes — produces either a frame,
//! a clean end-of-session (`Ok(None)` at a frame boundary) or a typed
//! [`ClusterError`], never a panic and never an attacker-sized
//! allocation. Encode→decode identity is pinned for every frame type,
//! including the elastic v3 frames (`ChunkAssign`/`ChunkPartials`/
//! `Rejoin`). Over 5,000 adversarial inputs execute per `cargo test`
//! run.

use parakmeans::cluster::wire::{
    read_frame_opt, write_frame, Frame, PhaseNs, MAX_FRAME_BYTES, WIRE_VERSION,
};
use parakmeans::error::{ClusterError, Error};
use parakmeans::linalg::kernel::DistancePolicy;
use parakmeans::testutil::prop::{self, Gen};

/// A randomized instance of every frame type (13 variants), round-
/// robined by `pick` so sweeps cover the full protocol surface.
fn gen_frame(g: &mut Gen, pick: usize) -> Frame {
    let policy = if g.bool() { DistancePolicy::Exact } else { DistancePolicy::Dot };
    let k = g.usize_in(1, 5) as u32;
    let dim = g.usize_in(1, 4) as u32;
    match pick % 13 {
        0 => Frame::Hello { version: g.usize_in(0, u16::MAX as usize) as u16 },
        1 => Frame::ShardSpec { rows: g.u64() >> g.usize_in(0, 63), dim },
        2 => Frame::Assign { k, dim, policy, centroids: g.points((k * dim) as usize, 1, 1e6) },
        3 => Frame::Partials {
            k,
            dim,
            counts: (0..k).map(|_| g.u64() >> 32).collect(),
            sums: (0..k * dim).map(|_| g.f64_in(-1e12, 1e12)).collect(),
            sse: g.f64_in(0.0, 1e15),
            phase: gen_phase(g),
        },
        4 => Frame::Gather { indices: (0..g.usize_in(0, 16)).map(|_| g.u64() >> 16).collect() },
        5 => {
            let rows = g.usize_in(0, 8);
            Frame::Rows { dim, rows: g.points(rows * dim as usize, 1, 1e3) }
        }
        6 => Frame::FetchAssign,
        7 => Frame::AssignShard {
            assign: (0..g.usize_in(0, 32)).map(|_| g.usize_in(0, 1 << 20) as i32 - 1).collect(),
        },
        8 => Frame::Shutdown,
        9 => Frame::ErrMsg {
            message: format!("fuzz error #{} with unicode é😀 and \"quotes\"", g.usize_in(0, 999)),
        },
        10 => {
            let lo = g.u64() >> 24;
            Frame::ChunkAssign {
                chunk: g.u64() >> 16,
                lo,
                hi: lo + g.usize_in(0, 1 << 16) as u64,
                k,
                dim,
                policy,
                want_assign: g.bool(),
                centroids: g.points((k * dim) as usize, 1, 1e6),
            }
        }
        11 => Frame::ChunkPartials {
            chunk: g.u64() >> 16,
            k,
            dim,
            counts: (0..k).map(|_| g.u64() >> 32).collect(),
            sums: (0..k * dim).map(|_| g.f64_in(-1e12, 1e12)).collect(),
            sse: g.f64_in(0.0, 1e15),
            assign: (0..g.usize_in(0, 16)).map(|_| g.usize_in(0, 99) as i32).collect(),
            phase: gen_phase(g),
        },
        _ => Frame::Rejoin { version: WIRE_VERSION },
    }
}

/// Half the partial frames carry the v4 phase block, half are
/// v3-shaped (`None` encodes zero bytes), so every sweep covers both
/// wire generations.
fn gen_phase(g: &mut Gen) -> Option<PhaseNs> {
    g.bool().then(|| PhaseNs { assign_ns: g.u64(), ser_ns: g.u64() })
}

fn encode(f: &Frame) -> Vec<u8> {
    let mut buf = Vec::new();
    write_frame(&mut buf, f).expect("in-memory encode cannot fail");
    buf
}

/// Decoding may succeed, may fail typed — but never panics, and any
/// `Err` must be the cluster taxonomy.
fn decode_is_total(bytes: &[u8], what: &str) -> prop::Outcome {
    let mut r = bytes;
    match read_frame_opt(&mut r) {
        Ok(_) => Ok(()),
        Err(Error::Cluster(_)) => Ok(()),
        Err(other) => Err(format!("{what}: non-cluster error {other:?} on {bytes:?}")),
    }
}

#[test]
fn encode_decode_identity_for_every_frame_type() {
    prop::check("wire roundtrip identity", 1300, |g| {
        let pick = g.usize_in(0, 12);
        let frame = gen_frame(g, pick);
        let buf = encode(&frame);
        let mut r = &buf[..];
        let (back, read) = read_frame_opt(&mut r)
            .map_err(|e| format!("decode failed on own encoding of {frame:?}: {e}"))?
            .ok_or_else(|| format!("own encoding of {frame:?} decoded as clean close"))?;
        prop::ensure(read as usize == buf.len(), "frame length accounting diverged")?;
        prop::ensure(r.is_empty(), "decoder left bytes behind")?;
        prop::ensure(back == frame, format!("roundtrip diverged: {frame:?} → {back:?}"))
    });
}

#[test]
fn truncation_at_every_boundary_is_clean_close_or_typed_error() {
    let mut g = Gen::new(0xf00d);
    let mut cases = 0u64;
    for pick in 0..13 {
        let frame = gen_frame(&mut g, pick);
        let buf = encode(&frame);
        for cut in 0..buf.len() {
            let mut r = &buf[..cut];
            match read_frame_opt(&mut r) {
                Ok(None) if cut == 0 => {} // clean close at the boundary
                Ok(other) => panic!(
                    "cut at {cut}/{} of {frame:?} decoded as {other:?}",
                    buf.len()
                ),
                Err(Error::Cluster(_)) => {} // typed, as required
                Err(other) => panic!("cut at {cut} of {frame:?}: non-cluster error {other:?}"),
            }
            cases += 1;
        }
    }
    assert!(cases >= 500, "expected a dense truncation sweep, got {cases}");
}

#[test]
fn bit_flipped_frames_never_panic() {
    prop::check("bit flips are survivable", 1500, |g| {
        let pick = g.usize_in(0, 12);
        let mut buf = encode(&gen_frame(g, pick));
        let edits = g.usize_in(1, 6);
        g.mutate(&mut buf, edits);
        decode_is_total(&buf, "bit-flipped frame")
    });
}

#[test]
fn random_soup_streams_terminate_with_typed_errors() {
    prop::check("soup streams terminate", 1200, |g| {
        let n = g.usize_in(0, 256);
        let soup = g.bytes(n);
        let mut r = &soup[..];
        // each successful read consumes ≥ 4 bytes, so the stream is
        // finite; the first error or clean close ends it
        loop {
            let before = r.len();
            match read_frame_opt(&mut r) {
                Ok(None) => return Ok(()),
                Ok(Some(_)) => {
                    prop::ensure(r.len() < before, "decoder made no progress")?;
                }
                Err(Error::Cluster(_)) => return Ok(()),
                Err(other) => return Err(format!("non-cluster error {other:?} on soup")),
            }
        }
    });
}

#[test]
fn oversized_length_prefix_is_rejected_before_any_body_read() {
    let mut buf = (MAX_FRAME_BYTES + 1).to_le_bytes().to_vec();
    buf.push(0x01);
    let err = read_frame_opt(&mut &buf[..]).unwrap_err();
    match err {
        Error::Cluster(ClusterError::Frame(msg)) => {
            assert!(msg.contains("implausible frame length"), "{msg}");
        }
        other => panic!("expected a typed frame error, got {other:?}"),
    }
}

#[test]
fn huge_but_legal_length_with_tiny_body_is_typed_truncation_not_oom() {
    // a forged 1 GiB length prefix followed by almost nothing: the
    // incremental body reader must fail typed after the bytes actually
    // sent, instead of allocating the promised gigabyte up front
    let mut buf = MAX_FRAME_BYTES.to_le_bytes().to_vec();
    buf.push(0x01); // type byte
    buf.extend_from_slice(&[0u8; 37]); // a dribble of body
    let err = read_frame_opt(&mut &buf[..]).unwrap_err();
    match err {
        Error::Cluster(ClusterError::Frame(msg)) => {
            assert!(msg.contains("truncated frame"), "{msg}");
        }
        other => panic!("expected a typed truncation error, got {other:?}"),
    }
}

#[test]
fn zero_length_prefix_is_typed() {
    let buf = 0u32.to_le_bytes().to_vec();
    let err = read_frame_opt(&mut &buf[..]).unwrap_err();
    assert!(matches!(err, Error::Cluster(ClusterError::Frame(_))), "{err:?}");
}

#[test]
fn v3_peers_interoperate_with_phase_carrying_frames() {
    // stripping the trailing 17-byte phase block (and re-patching the
    // length prefix) turns any v4 partials frame into its v3 encoding,
    // and it must decode to the same frame with `phase: None` — the
    // byte-prefix compatibility the MIN_WIRE_VERSION handshake relies
    // on. Conversely, any cut *inside* the phase block is typed.
    let mut g = Gen::new(0xbeef);
    for pick in [3usize, 11] {
        for _ in 0..200 {
            let mut frame = gen_frame(&mut g, pick);
            // force the block on so there is something to strip
            match &mut frame {
                Frame::Partials { phase, .. } | Frame::ChunkPartials { phase, .. } => {
                    *phase = Some(PhaseNs { assign_ns: g.u64(), ser_ns: g.u64() });
                }
                other => panic!("pick {pick} generated {other:?}"),
            }
            let buf = encode(&frame);
            const PHASE_BYTES: usize = 17; // marker + 2×u64
            let body = buf.len() - 4;
            let mut v3 = buf.clone();
            v3.truncate(buf.len() - PHASE_BYTES);
            v3[..4].copy_from_slice(&((body - PHASE_BYTES) as u32).to_le_bytes());
            let mut r = &v3[..];
            let (back, _) = read_frame_opt(&mut r)
                .expect("v3-shaped frame must decode")
                .expect("not a clean close");
            let want = match frame.clone() {
                Frame::Partials { k, dim, counts, sums, sse, .. } => {
                    Frame::Partials { k, dim, counts, sums, sse, phase: None }
                }
                Frame::ChunkPartials { chunk, k, dim, counts, sums, sse, assign, .. } => {
                    Frame::ChunkPartials { chunk, k, dim, counts, sums, sse, assign, phase: None }
                }
                other => unreachable!("{other:?}"),
            };
            assert_eq!(back, want, "v3 stripping changed the payload");
            // cuts inside the phase block: typed error, never a panic
            for cut in 1..PHASE_BYTES {
                let mut cut_frame = buf.clone();
                cut_frame.truncate(buf.len() - cut);
                cut_frame[..4].copy_from_slice(&((body - cut) as u32).to_le_bytes());
                match read_frame_opt(&mut &cut_frame[..]) {
                    Err(Error::Cluster(_)) => {}
                    other => panic!("cut {cut} inside phase block: {other:?}"),
                }
            }
        }
    }
}

#[test]
fn back_to_back_frames_stream_cleanly() {
    prop::check("multi-frame streams", 400, |g| {
        let count = g.usize_in(1, 6);
        let frames: Vec<Frame> = (0..count)
            .map(|i| {
                let pick = g.usize_in(0, 12) + i;
                gen_frame(g, pick)
            })
            .collect();
        let mut buf = Vec::new();
        for f in &frames {
            write_frame(&mut buf, f).unwrap();
        }
        let mut r = &buf[..];
        for (i, want) in frames.iter().enumerate() {
            let (got, _) = read_frame_opt(&mut r)
                .map_err(|e| format!("frame {i} failed: {e}"))?
                .ok_or_else(|| format!("stream ended early at frame {i}"))?;
            prop::ensure(&got == want, format!("frame {i} diverged"))?;
        }
        match read_frame_opt(&mut r) {
            Ok(None) => Ok(()),
            other => Err(format!("expected clean close after last frame, got {other:?}")),
        }
    });
}
