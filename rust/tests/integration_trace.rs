//! Integration: the tracing layer is contract-neutral and well-formed
//! (DESIGN.md §15).
//!
//! For every engine, a run with `--trace` installed must be
//! bit-identical to the same run untraced — spans wrap call sites, not
//! kernels, so the numeric fold never sees them. Each traced run must
//! emit one JSONL event per iteration, and every line must parse with
//! `util::json` carrying the full schema: `iter`, `sse`,
//! `empty_events`, `phase_ns` (all six phases), `per_worker`. The
//! distributed engine must additionally ship non-empty `per_worker`
//! rows (the wire-v4 piggyback).
//!
//! Trace state is process-global, so every test here serializes on one
//! mutex; engine runs only ever happen with the lock held, keeping one
//! test's iterations out of another test's trace file.

use std::path::PathBuf;
use std::sync::Mutex;
use std::time::Duration;

use parakmeans::cluster::LoopbackCluster;
use parakmeans::config::{DistSched, SchedMode};
use parakmeans::data::source::MemorySource;
use parakmeans::kmeans::dist::{self, DistOpts};
use parakmeans::kmeans::streaming::{self, StreamOpts};
use parakmeans::kmeans::{
    bisecting, elkan, hamerly, init, minibatch, parallel, serial, KmeansConfig, KmeansResult,
};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::util::json::Json;
use parakmeans::util::trace;

static LOCK: Mutex<()> = Mutex::new(());

/// Run `f` twice — untraced, then with a JSONL trace installed — and
/// return both results plus every parsed trace event.
fn run_twice<R>(name: &str, mut f: impl FnMut() -> R) -> (R, R, Vec<Json>) {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    // Drain any trace left installed by a poisoned earlier test.
    let _ = trace::finish();
    let plain = f();

    let path: PathBuf = std::env::temp_dir()
        .join(format!("parakm_trace_{name}_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    trace::install(Some(path.clone()), 0);
    let traced = f();
    let out = trace::finish().unwrap();
    assert_eq!(out.as_deref(), Some(path.as_path()), "{name}: finish returns the trace path");

    let text = std::fs::read_to_string(&path).unwrap();
    let events: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).unwrap_or_else(|e| panic!("{name}: unparseable line {l:?}: {e}")))
        .collect();
    let _ = std::fs::remove_file(&path);
    (plain, traced, events)
}

/// Every event carries the full §15 schema.
fn check_schema(events: &[Json], what: &str) {
    assert!(!events.is_empty(), "{what}: trace file is empty");
    for (i, e) in events.iter().enumerate() {
        assert!(
            e.get("iter").and_then(Json::as_usize).is_some(),
            "{what}: event {i} missing iter"
        );
        // sse may be null (elkan/hamerly converged-break emits NaN),
        // but the key itself must always be present
        assert!(e.get("sse").is_some(), "{what}: event {i} missing sse");
        assert!(
            e.get("empty_events").and_then(Json::as_usize).is_some(),
            "{what}: event {i} missing empty_events"
        );
        let phases = e.get("phase_ns").unwrap_or_else(|| panic!("{what}: event {i} phase_ns"));
        for p in trace::Phase::ALL {
            assert!(
                phases.get(p.name()).and_then(Json::as_f64).is_some(),
                "{what}: event {i} phase_ns missing {}",
                p.name()
            );
        }
        assert!(
            e.get("per_worker").and_then(Json::as_arr).is_some(),
            "{what}: event {i} missing per_worker"
        );
    }
}

/// The common assertion bundle for in-process engines.
fn check_engine(name: &str, f: impl FnMut() -> KmeansResult) {
    let (plain, traced, events) = run_twice(name, f);
    assert_bit_identical(&plain, &traced, &format!("{name}: traced vs untraced"));
    check_schema(&events, name);
    // engines emit one event per iteration (plus the converged-break
    // event the bounded engines record on their early-out pass)
    assert!(
        events.len() >= plain.iterations,
        "{name}: {} events for {} iterations",
        events.len(),
        plain.iterations
    );
}

fn dist_opts(sched: DistSched) -> DistOpts {
    DistOpts {
        connect_timeout: Duration::from_secs(5),
        io_timeout: Duration::from_secs(5),
        sched,
        ..Default::default()
    }
}

#[test]
fn serial_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(2, 1203);
    let cfg = KmeansConfig::new(5).with_seed(11);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    check_engine("serial", || serial::run_from(&ds, &cfg, &mu0));
}

#[test]
fn threads_static_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(2, 1301);
    let cfg = KmeansConfig::new(4).with_seed(3);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    check_engine("threads-static", || {
        parallel::run_from(&ds, &cfg, 3, parallel::MergeMode::Leader, &mu0)
    });
}

#[test]
fn threads_steal_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(3, 1107);
    let cfg = KmeansConfig::new(4).with_seed(9);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    check_engine("threads-steal", || {
        parallel::run_from_sched(
            &ds,
            &cfg,
            3,
            parallel::MergeMode::Leader,
            SchedMode::Steal,
            &mu0,
        )
    });
}

#[test]
fn oocore_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(2, 1409);
    let cfg = KmeansConfig::new(4).with_seed(17);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    let src = MemorySource::new(&ds);
    check_engine("oocore", || {
        streaming::run_from(&src, &cfg, &StreamOpts { shards: 2, chunk_rows: 257 }, &mu0).unwrap()
    });
}

#[test]
fn elkan_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(2, 1009);
    let cfg = KmeansConfig::new(5).with_seed(23);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    check_engine("elkan", || elkan::run_from(&ds, &cfg, &mu0));
}

#[test]
fn hamerly_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(3, 1013);
    let cfg = KmeansConfig::new(4).with_seed(29);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    check_engine("hamerly", || hamerly::run_from(&ds, &cfg, &mu0));
}

#[test]
fn minibatch_trace_is_contract_neutral() {
    let ds = parakmeans::eval::paper_dataset(2, 1511);
    let cfg = KmeansConfig::new(4).with_seed(31);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    check_engine("minibatch", || minibatch::run_from(&ds, &cfg, 128, &mu0));
}

#[test]
fn bisecting_trace_is_contract_neutral() {
    // bisecting routes every split through the serial core, so tracing
    // it exercises the serial spans over many sub-runs
    let ds = parakmeans::eval::paper_dataset(2, 907);
    let cfg = KmeansConfig::new(4).with_seed(37);
    let (plain, traced, events) = run_twice("bisecting", || bisecting::run(&ds, &cfg, 2));
    assert_bit_identical(&plain, &traced, "bisecting: traced vs untraced");
    check_schema(&events, "bisecting");
}

#[test]
fn dist_trace_carries_per_worker_rows() {
    let ds = parakmeans::eval::paper_dataset(2, 1207);
    let cfg = KmeansConfig::new(4).with_seed(41);
    let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
    for sched in [DistSched::Static, DistSched::Elastic] {
        let name = format!("dist-{sched:?}");
        let run = || {
            let cluster = LoopbackCluster::spawn_dataset(&ds, 2, 257).unwrap();
            let run = dist::run_from(&cluster.addrs, &cfg, &dist_opts(sched), &mu0).unwrap();
            cluster.join().unwrap();
            run.result
        };
        let (plain, traced, events) = run_twice(&name, run);
        assert_bit_identical(&plain, &traced, &format!("{name}: traced vs untraced"));
        check_schema(&events, &name);
        // the wire-v4 piggyback: shard-side timings reach the leader's
        // trace — at least one event with both workers reporting
        let populated = events.iter().any(|e| {
            e.get("per_worker").and_then(Json::as_arr).map(|a| a.len() == 2).unwrap_or(false)
        });
        assert!(populated, "{name}: no event carries 2 per_worker rows");
        for e in &events {
            for w in e.get("per_worker").and_then(Json::as_arr).unwrap() {
                assert!(w.get("worker").and_then(Json::as_usize).is_some(), "{name}: worker id");
                assert!(w.get("assign_ns").and_then(Json::as_f64).is_some(), "{name}: assign_ns");
                assert!(w.get("ser_ns").and_then(Json::as_f64).is_some(), "{name}: ser_ns");
            }
        }
    }
}

#[test]
fn trace_off_emits_nothing_but_counters_still_tick() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _ = trace::finish();
    let ds = parakmeans::eval::paper_dataset(2, 811);
    let cfg = KmeansConfig::new(3).with_seed(43);
    assert!(!trace::enabled());
    let before = trace::iterations_total();
    let r = serial::run(&ds, &cfg);
    assert!(trace::iterations_total() >= before + r.iterations as u64);
    assert!(!trace::enabled());
}
