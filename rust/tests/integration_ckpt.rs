//! Integration: durable checkpoint/resume with bit-identical recovery
//! (DESIGN.md §14) — the load-bearing acceptance for the `.pkc` layer.
//!
//! For every wired engine (serial, threads static+steal, elkan,
//! hamerly, oocore, dist static+elastic over loopback TCP) the matrix
//! kills a checkpointed run at three points — right after a
//! checkpoint, mid-iteration between sparse checkpoints, and mid-
//! checkpoint-write (a torn slot the loader must fall back from) —
//! then resumes and demands the final centroids, assignments, SSE and
//! iteration count equal the uninterrupted run bit for bit. A fourth
//! leg resumes an already-finished run, exercising every engine's
//! terminal completion path (one assignment-only pass, zero Lloyd
//! iterations).
//!
//! "Killed after iteration j" is simulated as a run with
//! `max_iters = j`: the engines checkpoint at iteration boundaries, so
//! a run truncated at j leaves exactly the on-disk state a SIGKILL
//! after iteration j would (the CI ckpt-smoke job kills a real
//! process with a real SIGKILL to close that gap).

use std::path::{Path, PathBuf};
use std::time::Duration;

use parakmeans::cluster::LoopbackCluster;
use parakmeans::config::{DistSched, SchedMode};
use parakmeans::data::io;
use parakmeans::data::source::MemorySource;
use parakmeans::data::{Dataset, MixtureSpec};
use parakmeans::error::Error;
use parakmeans::kmeans::ckpt::{self, CkptSink, CkptState};
use parakmeans::kmeans::dist::{self, DistOpts};
use parakmeans::kmeans::streaming::{self, StreamOpts};
use parakmeans::kmeans::{elkan, hamerly, parallel, serial, KmeansConfig, KmeansResult};
use parakmeans::testutil::assert_bit_identical;

fn tmp(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("parakm_ckpt_it_{}_{tag}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Truncate the slot `ckpt::load` would pick to half its bytes — the
/// on-disk state after a crash midway through a checkpoint write that
/// bypassed the temp-file+rename discipline (the worst torn write).
fn tear_best_slot(dir: &Path) {
    let mut best: Option<(PathBuf, u64)> = None;
    for name in ["ckpt_a.pkc", "ckpt_b.pkc"] {
        let p = dir.join(name);
        if let Ok(bytes) = std::fs::read(&p) {
            if let Ok(st) = io::decode_ckpt(&bytes) {
                if best.as_ref().map(|&(_, it)| st.iteration > it).unwrap_or(true) {
                    best = Some((p.clone(), st.iteration));
                }
            }
        }
    }
    let (p, _) = best.expect("torn-write leg needs at least one decodable slot");
    let bytes = std::fs::read(&p).unwrap();
    std::fs::write(&p, &bytes[..bytes.len() / 2]).unwrap();
}

type EngineFn<'a> = &'a dyn Fn(&KmeansConfig, Option<&CkptSink>, Option<CkptState>) -> KmeansResult;

/// The kill × resume matrix for one engine. `tol = 0` pins the
/// iteration count to the budget, so every kill point is reached and
/// "converged early" cannot mask a replay divergence.
fn kill_resume_matrix(tag: &str, fp_engine: &str, fp_sched: &str, n: usize, d: usize, k: usize, run: EngineFn<'_>) {
    let full = KmeansConfig::new(k).with_seed(13).with_tol(0.0).with_max_iters(9);
    let fp = ckpt::fingerprint(fp_engine, fp_sched, &full, n, d);
    let uninterrupted = run(&full, None, None);
    assert_eq!(uninterrupted.iterations, 9, "{tag}: tol 0 must run the full budget");

    // kill right after a checkpoint: every-iteration cadence, die at 4
    {
        let dir = tmp(&format!("{tag}_after"));
        let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
        let _ = run(&full.clone().with_max_iters(4), Some(&sink), None);
        let state = ckpt::load_validated(&dir, &fp).unwrap();
        assert_eq!(state.iteration, 4, "{tag}: newest slot");
        let resumed = run(&full, None, Some(state));
        assert_bit_identical(&uninterrupted, &resumed, &format!("{tag}: kill after ckpt"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    // kill mid-iteration: sparse cadence (every 3), die at 5 — the two
    // un-checkpointed iterations are lost and must replay identically
    {
        let dir = tmp(&format!("{tag}_mid"));
        let sink = CkptSink::create(&dir, 3, fp.clone()).unwrap();
        let _ = run(&full.clone().with_max_iters(5), Some(&sink), None);
        let state = ckpt::load_validated(&dir, &fp).unwrap();
        assert_eq!(state.iteration, 3, "{tag}: sparse cadence snapshots at 3");
        let resumed = run(&full, None, Some(state));
        assert_bit_identical(&uninterrupted, &resumed, &format!("{tag}: kill mid-iteration"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    // kill mid-checkpoint-write: the newest slot is torn; the loader
    // must fall back to the older intact slot and still recover exactly
    {
        let dir = tmp(&format!("{tag}_torn"));
        let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
        let _ = run(&full.clone().with_max_iters(4), Some(&sink), None);
        tear_best_slot(&dir);
        let state = ckpt::load_validated(&dir, &fp).unwrap();
        assert_eq!(state.iteration, 3, "{tag}: fallback to the intact A/B slot");
        let resumed = run(&full, None, Some(state));
        assert_bit_identical(&uninterrupted, &resumed, &format!("{tag}: torn checkpoint write"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    // resume of a finished run: terminal state, zero further Lloyd
    // iterations, one assignment-only pass — still bit-identical
    {
        let dir = tmp(&format!("{tag}_term"));
        let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
        let _ = run(&full, Some(&sink), None);
        let state = ckpt::load_validated(&dir, &fp).unwrap();
        assert_eq!(state.iteration, 9, "{tag}: terminal snapshot");
        let resumed = run(&full, None, Some(state));
        assert_bit_identical(&uninterrupted, &resumed, &format!("{tag}: resume when complete"));
        let _ = std::fs::remove_dir_all(&dir);
    }
}

fn paper_ds() -> Dataset {
    MixtureSpec::paper_2d(8).generate(2003, 13)
}

#[test]
fn serial_kill_resume_matrix() {
    let ds = paper_ds();
    kill_resume_matrix("serial", "serial", "none", ds.len(), ds.dim(), 8, &|cfg, sink, resume| {
        serial::run_ckpt(&ds, cfg, sink, resume).unwrap()
    });
}

#[test]
fn threads_static_kill_resume_matrix() {
    let ds = paper_ds();
    kill_resume_matrix("threads_static", "threads", "static", ds.len(), ds.dim(), 8, &|cfg, sink, resume| {
        parallel::run_sched_ckpt(&ds, cfg, 3, parallel::MergeMode::Leader, SchedMode::Static, sink, resume)
            .unwrap()
    });
}

#[test]
fn threads_steal_kill_resume_matrix() {
    let ds = paper_ds();
    kill_resume_matrix("threads_steal", "threads", "steal", ds.len(), ds.dim(), 8, &|cfg, sink, resume| {
        parallel::run_sched_ckpt(&ds, cfg, 3, parallel::MergeMode::Leader, SchedMode::Steal, sink, resume)
            .unwrap()
    });
}

#[test]
fn elkan_kill_resume_matrix() {
    let ds = paper_ds();
    kill_resume_matrix("elkan", "elkan", "steal", ds.len(), ds.dim(), 8, &|cfg, sink, resume| {
        elkan::run_ckpt(&ds, cfg, 3, SchedMode::Steal, sink, resume).unwrap()
    });
}

#[test]
fn hamerly_kill_resume_matrix() {
    let ds = paper_ds();
    kill_resume_matrix("hamerly", "hamerly", "steal", ds.len(), ds.dim(), 8, &|cfg, sink, resume| {
        hamerly::run_ckpt(&ds, cfg, 3, SchedMode::Steal, sink, resume).unwrap()
    });
}

#[test]
fn oocore_kill_resume_matrix() {
    let ds = paper_ds();
    let opts = StreamOpts { shards: 3, chunk_rows: 257 };
    kill_resume_matrix("oocore", "oocore", "static", ds.len(), ds.dim(), 8, &|cfg, sink, resume| {
        let src = MemorySource::new(&ds);
        streaming::run_ckpt(&src, cfg, &opts, sink, resume).unwrap()
    });
}

fn dist_opts(sched: DistSched) -> DistOpts {
    DistOpts {
        connect_timeout: Duration::from_secs(5),
        io_timeout: Duration::from_secs(10),
        sched,
        retry: 2,
    }
}

#[test]
fn dist_static_kill_resume_matrix() {
    let ds = MixtureSpec::paper_3d(4).generate(1203, 13);
    kill_resume_matrix("dist_static", "dist", "static", ds.len(), ds.dim(), 4, &|cfg, sink, resume| {
        let cluster = LoopbackCluster::spawn_dataset(&ds, 2, 256).unwrap();
        let run = dist::run_ckpt(&cluster.addrs, cfg, &dist_opts(DistSched::Static), sink, resume)
            .unwrap();
        cluster.join().unwrap();
        run.result
    });
}

#[test]
fn dist_elastic_kill_resume_matrix() {
    let ds = MixtureSpec::paper_3d(4).generate(1203, 13);
    kill_resume_matrix("dist_elastic", "dist", "elastic", ds.len(), ds.dim(), 4, &|cfg, sink, resume| {
        let cluster = LoopbackCluster::spawn_replicated(&ds, 2, 256).unwrap();
        let run = dist::run_ckpt(&cluster.addrs, cfg, &dist_opts(DistSched::Elastic), sink, resume)
            .unwrap();
        cluster.join().unwrap();
        run.result
    });
}

// ---- refusal paths: a wrong or broken checkpoint fails loudly ----------

#[test]
fn fingerprint_mismatch_refuses_to_resume() {
    let ds = paper_ds();
    let cfg = KmeansConfig::new(8).with_seed(13).with_tol(0.0).with_max_iters(3);
    let fp = ckpt::fingerprint("serial", "none", &cfg, ds.len(), ds.dim());
    let dir = tmp("fp_mismatch");
    let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
    serial::run_ckpt(&ds, &cfg, Some(&sink), None).unwrap();

    // wrong seed: a resume under a different RNG stream is a different run
    let other_seed = ckpt::fingerprint("serial", "none", &cfg.clone().with_seed(14), ds.len(), ds.dim());
    let err = ckpt::load_validated(&dir, &other_seed).unwrap_err();
    assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
    assert!(err.to_string().contains("seed"), "{err}");

    // wrong engine family
    let other_engine = ckpt::fingerprint("threads", "static", &cfg, ds.len(), ds.dim());
    let err = ckpt::load_validated(&dir, &other_engine).unwrap_err();
    assert!(err.to_string().contains("engine"), "{err}");

    // wrong dataset size
    let other_n = ckpt::fingerprint("serial", "none", &cfg, ds.len() + 1, ds.dim());
    let err = ckpt::load_validated(&dir, &other_n).unwrap_err();
    assert!(err.to_string().contains("mismatch on n"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn resume_against_a_different_dataset_fails_typed() {
    // same shape fingerprint path as the engines hit in-engine: the
    // snapshot says n = 2003, the dataset offered for resume has fewer
    // rows — typed Error::Ckpt, never an index panic
    let ds = paper_ds();
    let cfg = KmeansConfig::new(8).with_seed(13).with_tol(0.0).with_max_iters(3);
    let fp = ckpt::fingerprint("serial", "none", &cfg, ds.len(), ds.dim());
    let dir = tmp("wrong_ds");
    let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
    serial::run_ckpt(&ds, &cfg, Some(&sink), None).unwrap();
    let state = ckpt::load_validated(&dir, &fp).unwrap();

    let smaller = MixtureSpec::paper_2d(8).generate(1999, 13);
    let err = serial::run_ckpt(&smaller, &cfg, None, Some(state)).unwrap_err();
    assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn both_slots_corrupt_is_a_typed_load_error() {
    let ds = paper_ds();
    let cfg = KmeansConfig::new(8).with_seed(13).with_tol(0.0).with_max_iters(3);
    let fp = ckpt::fingerprint("serial", "none", &cfg, ds.len(), ds.dim());
    let dir = tmp("all_corrupt");
    let sink = CkptSink::create(&dir, 1, fp.clone()).unwrap();
    serial::run_ckpt(&ds, &cfg, Some(&sink), None).unwrap();
    for name in ["ckpt_a.pkc", "ckpt_b.pkc"] {
        let p = dir.join(name);
        if p.exists() {
            std::fs::write(&p, b"not a checkpoint at all").unwrap();
        }
    }
    let err = ckpt::load_validated(&dir, &fp).unwrap_err();
    assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn empty_directory_is_a_typed_load_error() {
    let dir = tmp("empty");
    let err = ckpt::load(&dir).unwrap_err();
    assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
    assert!(err.to_string().contains("no loadable checkpoint"), "{err}");
    let _ = std::fs::remove_dir_all(&dir);
}
