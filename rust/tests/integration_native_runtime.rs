//! Integration: the coordinator engines and the serving batcher over
//! the native executor backend — no AOT artifacts required. These are
//! the "all engines share one hot path" claims in executable form:
//! shared/offload/streaming must reproduce pure-rust serial Lloyd from
//! the same init, artifact-free.

use std::path::{Path, PathBuf};

use parakmeans::config::RunConfig;
use parakmeans::coordinator::shared::MergePolicy;
use parakmeans::coordinator::{offload, shared, streaming};
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::data::io;
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::metrics;
use parakmeans::runtime::Runtime;

/// Artifacts dir that never exists: forces the native fallback even on
/// machines where `make artifacts` has run.
fn native_dir() -> PathBuf {
    std::env::temp_dir().join("parakm_native_rt_tests/no_artifacts_here")
}

fn cfg(k: usize) -> RunConfig {
    RunConfig { k, seed: 42, artifacts_dir: native_dir(), ..Default::default() }
}

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("parakm_native_rt_tests");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn runtime_falls_back_to_native() {
    let rt = Runtime::new_or_native(&native_dir()).unwrap();
    assert!(rt.is_native_fallback());
}

#[test]
fn shared_engine_native_matches_serial() {
    let ds = MixtureSpec::paper_3d(4).generate(40_001, 3); // ragged shards + padded tail
    let c = cfg(4);
    let run = shared::run(&ds, &c, 4).unwrap();
    assert!(run.result.converged);

    let kc = KmeansConfig::new(4).with_seed(c.seed);
    let mu0 = kmeans::init::initialize(&ds, 4, c.init, c.seed);
    let reference = kmeans::serial::run_from(&ds, &kc, &mu0);
    assert_eq!(run.result.iterations, reference.iterations);
    let ari = metrics::adjusted_rand_index(&run.result.assign, &reference.assign);
    assert!(ari > 0.9999, "ari {ari}");
    let rel = (run.result.sse - reference.sse).abs() / reference.sse;
    assert!(rel < 1e-4, "sse rel err {rel}");
}

#[test]
fn shared_worker_count_and_merge_policy_invariant() {
    let ds = MixtureSpec::paper_3d(4).generate(20_000, 5);
    let c = cfg(4);
    let a = shared::run(&ds, &c, 1).unwrap();
    let b = shared::run(&ds, &c, 8).unwrap();
    assert_eq!(a.result.assign, b.result.assign);
    assert_eq!(a.result.iterations, b.result.iterations);
    let crit = shared::run_opts(&ds, &c, 8, MergePolicy::Critical).unwrap();
    assert_eq!(a.result.assign, crit.result.assign);
}

#[test]
fn offload_engine_native_matches_serial_and_chunk_invariant() {
    let ds = MixtureSpec::paper_3d(4).generate(30_001, 11);
    let auto = offload::run(&ds, &cfg(4)).unwrap();

    let kc = KmeansConfig::new(4).with_seed(42);
    let mu0 = kmeans::init::initialize(&ds, 4, cfg(4).init, 42);
    let reference = kmeans::serial::run_from(&ds, &kc, &mu0);
    assert_eq!(auto.result.iterations, reference.iterations);
    let ari = metrics::adjusted_rand_index(&auto.result.assign, &reference.assign);
    assert!(ari > 0.9999, "ari {ari}");

    // pinning the chunk must not change the clustering, only the plan
    let pinned = offload::run(&ds, &RunConfig { chunk: 4096, ..cfg(4) }).unwrap();
    assert_eq!(auto.result.assign, pinned.result.assign);
    assert!(auto.exec_calls <= pinned.exec_calls, "auto plan should use fewer calls");
}

#[test]
fn offload_2d_k11_padding_path() {
    // K = 11 exercises non-power-of-two k through the kernel tiles
    let ds = MixtureSpec::paper_2d(8).generate(15_000, 5);
    let c = RunConfig { k: 11, seed: 7, artifacts_dir: native_dir(), ..Default::default() };
    let off = offload::run(&ds, &c).unwrap();
    let kc = KmeansConfig::new(11).with_seed(7);
    let mu0 = kmeans::init::initialize(&ds, 11, c.init, 7);
    let reference = kmeans::serial::run_from(&ds, &kc, &mu0);
    assert_eq!(off.result.iterations, reference.iterations);
    let ari = metrics::adjusted_rand_index(&off.result.assign, &reference.assign);
    assert!(ari > 0.999, "ari {ari}");
}

#[test]
fn streaming_engine_native_matches_serial() {
    let ds = MixtureSpec::paper_3d(4).generate(25_001, 9);
    let path = tmp("stream_native.pkd");
    io::write_binary(&path, &ds).unwrap();
    let run = streaming::run_file(&path, &cfg(4)).unwrap();
    assert!(run.result.converged);

    let info = streaming::probe(&path).unwrap();
    assert_eq!((info.n, info.dim), (25_001, 3));
    // serial reference from the same reservoir init (same seed)
    let mu0 = {
        // reservoir_init is private; reproduce via a fresh streaming
        // run's property instead: assignments must partition the data
        run.result.cluster_sizes()
    };
    assert_eq!(mu0.iter().sum::<usize>(), 25_001);
    assert!(run.result.assign.iter().all(|&a| (0..4).contains(&a)));
}

#[test]
fn shared_engine_any_shape_runs_artifact_free() {
    // specs are synthesized on demand in native fallback mode, so a
    // k far beyond the enumerated matrix still runs — and matches the
    // pure-rust serial engine from the same init
    let ds = MixtureSpec::paper_2d(8).generate(2_000, 1);
    let c = cfg(99);
    let run = shared::run(&ds, &c, 2).unwrap();
    assert_eq!(run.result.k, 99);
    // a valid partition over all 99 clusters' worth of labels
    let sizes = run.result.cluster_sizes();
    assert_eq!(sizes.iter().sum::<usize>(), 2_000);
    assert!(run.result.assign.iter().all(|&a| (0..99).contains(&a)));
    // and in the same objective ballpark as pure-rust serial Lloyd
    let kc = KmeansConfig::new(99).with_seed(c.seed);
    let mu0 = kmeans::init::initialize(&ds, 99, c.init, c.seed);
    let reference = kmeans::serial::run_from(&ds, &kc, &mu0);
    let rel = (run.result.sse - reference.sse).abs() / reference.sse;
    assert!(rel < 0.05, "sse rel err {rel}");

    // degenerate configs still fail cleanly before any runtime work
    let err = shared::run(&ds, &cfg(0), 2).unwrap_err();
    assert!(matches!(err, parakmeans::Error::Config(_)), "{err}");
}

#[test]
fn batcher_native_assigns_to_nearest() {
    use parakmeans::serve::{Batcher, BatcherConfig, Request, Response};
    use std::sync::mpsc;

    let ds = MixtureSpec::paper_3d(4).generate(5000, 3);
    let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
    let centroids = model.centroids.clone();
    let mut b = Batcher::new(
        Path::new(&native_dir()),
        centroids.clone(),
        3,
        4,
        BatcherConfig::default(),
    )
    .unwrap();

    let pts: Vec<Vec<f64>> =
        (0..64).map(|i| ds.point(i).iter().map(|&v| v as f64).collect()).collect();
    let (tx, rx) = mpsc::channel();
    b.flush(vec![parakmeans::serve::batcher::Job {
        request: Request { id: 1, points: pts.clone() },
        reply: tx,
    }]);
    match rx.recv().unwrap() {
        Response::Ok { id, clusters, distances } => {
            assert_eq!(id, 1);
            assert_eq!(clusters.len(), 64);
            for (i, &c) in clusters.iter().enumerate() {
                let p: Vec<f32> = pts[i].iter().map(|&v| v as f32).collect();
                let mut best = 0;
                let mut best_d = f32::INFINITY;
                for cc in 0..4 {
                    let d = parakmeans::linalg::sqdist(&p, &centroids[cc * 3..cc * 3 + 3]);
                    if d < best_d {
                        best_d = d;
                        best = cc as i32;
                    }
                }
                assert_eq!(c, best, "point {i}");
                assert!((distances[i] - best_d).abs() < 1e-4);
            }
        }
        other => panic!("unexpected {other:?}"),
    }
    assert_eq!(b.stats.device_calls, 1);
}

#[test]
fn eval_dispatch_all_engines_native() {
    use parakmeans::config::Engine;
    // route AOT-backed engines through the eval dispatcher with the
    // default (absent) artifacts dir — exercises the thread-local
    // runtime cache over the native backend
    let ds = parakmeans::eval::paper_dataset(3, 8_000);
    let mut sses = Vec::new();
    for engine in [
        Engine::Serial,
        Engine::Threads,
        Engine::Elkan,
        Engine::Hamerly,
        Engine::Shared,
        Engine::Offload,
        Engine::Streaming,
    ] {
        let t = parakmeans::eval::run_engine(engine, &ds, 4, 4, 42).unwrap();
        assert!(t.converged, "{engine} did not converge");
        if engine != Engine::Streaming {
            // streaming uses reservoir init (different start point)
            sses.push(t.sse);
        }
    }
    let base = sses[0];
    for (i, s) in sses.iter().enumerate() {
        assert!((s - base).abs() / base < 1e-3, "engine {i} sse {s} vs {base}");
    }
}
