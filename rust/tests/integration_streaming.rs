//! Integration: the out-of-core streaming engine against the in-memory
//! engines on the paper's 2D/3D GMM datasets — the acceptance claim of
//! the chunked-accumulation contract in executable form:
//!
//! - a streaming run whose memory budget is far smaller than the
//!   dataset (file-backed and generator-backed) completes and is
//!   **bit-identical** to the in-memory serial engine (one shard
//!   replays the serial fold exactly);
//! - a sharded streaming run is **bit-identical** to the threaded
//!   engine at the same shard count, for every chunk size;
//! - the `parakm` binary round-trips `gen-data --chunk` →
//!   `run --engine oocore --memory-budget` end to end.

use std::path::PathBuf;
use std::process::Command;

use parakmeans::data::source::{DataSource, FileSource, GmmSource, MemorySource};
use parakmeans::data::{io, Dataset};
use parakmeans::eval;
use parakmeans::kmeans::streaming::{run_from, StreamOpts};
use parakmeans::kmeans::{self, init, KmeansConfig};
use parakmeans::metrics;
use parakmeans::testutil::assert_bit_identical;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("parakm_integration_streaming");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// Resolve a budget that is a small fraction of the dataset payload,
/// asserting it really is smaller (the acceptance premise).
fn tight_opts(ds: &Dataset, shards: usize, divisor: usize) -> StreamOpts {
    let payload = ds.len() * ds.dim() * 4;
    let budget = payload / divisor;
    let opts = StreamOpts::resolve(ds.dim(), shards, 0, budget).unwrap();
    assert!(
        opts.buffer_bytes(ds.dim()) <= budget && budget < payload,
        "budget {budget} not below payload {payload}"
    );
    opts
}

/// The acceptance criterion: file-backed streaming under a memory
/// budget ~10× smaller than the dataset, bit-identical to serial on
/// both paper families.
#[test]
fn file_backed_budgeted_run_is_bit_identical_to_serial() {
    for (dim, n, k) in [(2usize, 20_003usize, 8usize), (3, 30_001, 4)] {
        let ds = eval::paper_dataset(dim, n);
        let path = tmp(&format!("paper_{dim}d.pkd"));
        io::write_binary(&path, &ds).unwrap();

        let cfg = KmeansConfig::new(k).with_seed(42);
        let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
        let serial = kmeans::serial::run_from(&ds, &cfg, &mu0);
        assert!(serial.iterations > 1, "degenerate reference ({dim}D)");

        let src = FileSource::open(&path).unwrap();
        let opts = tight_opts(&ds, 1, 10);
        assert!(opts.chunk_rows < n, "budget must force multiple chunks");
        let streamed = run_from(&src, &cfg, &opts, &mu0).unwrap();
        assert_bit_identical(&streamed, &serial, &format!("paper {dim}D file-backed"));
    }
}

/// Generator-backed: the dataset is never on disk either — n is
/// bounded by neither RAM nor storage. Bit-identical to serial run on
/// the materialized rows.
#[test]
fn generator_backed_budgeted_run_is_bit_identical_to_serial() {
    for (dim, n, k) in [(2usize, 15_000usize, 8usize), (3, 15_000, 4)] {
        let gmm = GmmSource::paper(dim, n, 7).unwrap();
        let ds = gmm.materialize();

        let cfg = KmeansConfig::new(k).with_seed(3);
        let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
        let serial = kmeans::serial::run_from(&ds, &cfg, &mu0);

        let opts = tight_opts(&ds, 1, 8);
        let streamed = run_from(&gmm, &cfg, &opts, &mu0).unwrap();
        assert_bit_identical(&streamed, &serial, &format!("paper {dim}D generator-backed"));
    }
}

/// Sharded: S streaming shards == threaded engine at p = S, bit for
/// bit, for every chunk size — and the clustering agrees with serial.
#[test]
fn sharded_budgeted_run_matches_threads_exactly() {
    let ds = eval::paper_dataset(3, 24_001);
    let k = 4;
    let cfg = KmeansConfig::new(k).with_seed(42);
    let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
    let serial = kmeans::serial::run_from(&ds, &cfg, &mu0);
    let path = tmp("paper_3d_sharded.pkd");
    io::write_binary(&path, &ds).unwrap();
    let src = FileSource::open(&path).unwrap();

    for shards in [2usize, 4, 7] {
        let threads = kmeans::parallel::run_from(
            &ds,
            &cfg,
            shards,
            kmeans::parallel::MergeMode::Leader,
            &mu0,
        );
        for divisor in [5usize, 50] {
            let opts = tight_opts(&ds, shards, divisor);
            let streamed = run_from(&src, &cfg, &opts, &mu0).unwrap();
            assert_bit_identical(
                &streamed,
                &threads,
                &format!("shards={shards} divisor={divisor}"),
            );
        }
        // and the sharded clustering matches serial's partition
        let ari = metrics::adjusted_rand_index(&threads.assign, &serial.assign);
        assert!(ari > 0.9999, "shards={shards} diverged from serial: ARI {ari}");
    }
}

/// Same data via memory, file and generator sources: identical results.
#[test]
fn all_sources_agree_bitwise() {
    let gmm = GmmSource::paper(2, 8_000, 19).unwrap();
    let ds = gmm.materialize();
    let path = tmp("sources_2d.pkd");
    io::write_binary(&path, &ds).unwrap();
    let file = FileSource::open(&path).unwrap();

    let cfg = KmeansConfig::new(8).with_seed(1);
    let mu0 = init::initialize(&ds, 8, cfg.init, cfg.seed);
    let opts = StreamOpts { shards: 3, chunk_rows: 512 };

    let mem = run_from(&MemorySource::new(&ds), &cfg, &opts, &mu0).unwrap();
    let fil = run_from(&file, &cfg, &opts, &mu0).unwrap();
    let gen = run_from(&gmm, &cfg, &opts, &mu0).unwrap();
    assert_bit_identical(&fil, &mem, "file vs memory");
    assert_bit_identical(&gen, &mem, "generator vs memory");
    // truth labels travel through all three sources identically
    assert_eq!(file.truth().unwrap(), ds.truth);
    assert_eq!(gmm.truth().unwrap(), ds.truth);
}

// ---- CLI round trip -----------------------------------------------------

fn parakm() -> Command {
    Command::new(env!("CARGO_BIN_EXE_parakm"))
}

#[test]
fn cli_gen_data_chunked_write_is_byte_identical() {
    for ext in ["pkd", "csv"] {
        let whole = tmp(&format!("cli_whole.{ext}"));
        let chunked = tmp(&format!("cli_chunked.{ext}"));
        for (out, extra) in [(&whole, None), (&chunked, Some(["--chunk", "997"]))] {
            let mut cmd = parakm();
            cmd.args(["gen-data", "--dim", "3", "--n", "10000", "--out"]).arg(out);
            if let Some(flags) = extra {
                cmd.args(flags);
            }
            let o = cmd.output().unwrap();
            assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
        }
        assert_eq!(
            std::fs::read(&whole).unwrap(),
            std::fs::read(&chunked).unwrap(),
            "streamed gen-data changed the .{ext} bytes"
        );
    }
}

#[test]
fn cli_oocore_run_under_memory_budget() {
    let data = tmp("cli_oocore.pkd");
    let o = parakm()
        .args(["gen-data", "--dim", "3", "--n", "20000", "--out"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));

    // payload is 240 KB; a 128K budget forces chunked streaming while
    // still affording the 80 KB truth fetch, so ARI must be computed
    let o = parakm()
        .args(["run", "--engine", "oocore", "--k", "4", "--memory-budget", "128K", "--input"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let text = String::from_utf8_lossy(&o.stdout);
    assert!(text.contains("engine      : oocore"), "{text}");
    assert!(text.contains("converged: true"), "{text}");
    assert!(text.contains("never resident"), "{text}");
    assert!(text.contains("ARI vs truth: "), "{text}");
    assert!(!text.contains("skipped"), "{text}");

    // a budget below the truth-label bytes skips ARI, visibly
    let o = parakm()
        .args(["run", "--engine", "oocore", "--k", "4", "--memory-budget", "24K", "--input"])
        .arg(&data)
        .output()
        .unwrap();
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let text = String::from_utf8_lossy(&o.stdout);
    assert!(text.contains("ARI vs truth: skipped"), "{text}");
}

#[test]
fn cli_oocore_synthetic_source() {
    let o = parakm()
        .args([
            "run", "--engine", "oocore", "--k", "4", "--synthetic", "3d:12000",
            "--memory-budget", "64K", "--threads", "2",
        ])
        .output()
        .unwrap();
    assert!(o.status.success(), "{}", String::from_utf8_lossy(&o.stderr));
    let text = String::from_utf8_lossy(&o.stdout);
    assert!(text.contains("gmm(3D"), "{text}");
    assert!(text.contains("converged: true"), "{text}");
}

#[test]
fn cli_oocore_rejects_contradictory_budget() {
    let o = parakm()
        .args([
            "run", "--engine", "oocore", "--k", "4", "--synthetic", "3d:10000",
            "--chunk", "100000", "--memory-budget", "1K",
        ])
        .output()
        .unwrap();
    assert!(!o.status.success());
    let err = String::from_utf8_lossy(&o.stderr);
    assert!(err.contains("exceeds --memory-budget"), "{err}");
}
