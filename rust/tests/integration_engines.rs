//! Integration: every engine against every other, over the real AOT
//! artifacts, on datasets sized to exercise multi-chunk planning,
//! padded tails and ragged shards. These tests are the repo's
//! "Figures 1–6" claim in executable form: all engines produce the
//! same clustering as serial Lloyd from the same init.

use parakmeans::config::{Engine, RunConfig};
use parakmeans::coordinator::shared::MergePolicy;
use parakmeans::coordinator::{offload, shared};
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::eval;
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::metrics;

fn artifacts_ready() -> bool {
    std::path::Path::new("artifacts/manifest.json").exists()
}

fn cfg(k: usize) -> RunConfig {
    RunConfig { k, seed: 42, ..Default::default() }
}

/// All engines, one mid-size 3D workload, pairwise agreement.
#[test]
fn all_engines_agree_3d() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = MixtureSpec::paper_3d(4).generate(70_001, 3); // ragged
    let kc = KmeansConfig::new(4).with_seed(42);
    let mu0 = kmeans::init::initialize(&ds, 4, kc.init, kc.seed);

    let serial = kmeans::serial::run_from(&ds, &kc, &mu0);
    let threads =
        kmeans::parallel::run_from(&ds, &kc, 4, kmeans::parallel::MergeMode::Leader, &mu0);
    let elkan = kmeans::elkan::run_from(&ds, &kc, &mu0);
    let hamerly = kmeans::hamerly::run_from(&ds, &kc, &mu0);
    let sh = shared::run(&ds, &cfg(4), 4).unwrap();
    let off = offload::run(&ds, &cfg(4)).unwrap();

    for (name, assign) in [
        ("threads", &threads.assign),
        ("elkan", &elkan.assign),
        ("hamerly", &hamerly.assign),
        ("shared", &sh.result.assign),
        ("offload", &off.result.assign),
    ] {
        let ari = metrics::adjusted_rand_index(&serial.assign, assign);
        assert!(ari > 0.999, "{name} diverged from serial: ARI {ari}");
    }
    assert_eq!(serial.iterations, sh.result.iterations, "AOT iteration count");
    assert_eq!(serial.iterations, off.result.iterations);
}

/// 2D / K=11 (the Figures 5-6 workload): the K-padding path (11 -> 16
/// lanes) through the kernel must not change results.
#[test]
fn k11_padding_path_2d() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = MixtureSpec::paper_2d(8).generate(50_000, 5);
    let kc = KmeansConfig::new(11).with_seed(7);
    let mu0 = kmeans::init::initialize(&ds, 11, kc.init, kc.seed);
    let serial = kmeans::serial::run_from(&ds, &kc, &mu0);
    let c = RunConfig { k: 11, seed: 7, ..Default::default() };
    let off = offload::run(&ds, &c).unwrap();
    let ari = metrics::adjusted_rand_index(&serial.assign, &off.result.assign);
    assert!(ari > 0.999, "K=11 offload diverged: ARI {ari}");
    assert_eq!(serial.iterations, off.result.iterations);
}

/// Merge policies must be numerically identical (only the virtual
/// clock differs).
#[test]
fn merge_policies_identical_results() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = MixtureSpec::paper_3d(4).generate(30_000, 9);
    let a = shared::run_opts(&ds, &cfg(4), 8, MergePolicy::Leader).unwrap();
    let b = shared::run_opts(&ds, &cfg(4), 8, MergePolicy::Critical).unwrap();
    assert_eq!(a.result.assign, b.result.assign);
    assert_eq!(a.result.centroids, b.result.centroids);
}

/// Chunk configuration must not change results: auto vs pinned sizes.
#[test]
fn chunk_invariance() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = MixtureSpec::paper_3d(4).generate(20_000, 11);
    let auto = offload::run(&ds, &cfg(4)).unwrap();
    let pinned = offload::run(&ds, &RunConfig { chunk: 4096, ..cfg(4) }).unwrap();
    assert_eq!(auto.result.assign, pinned.result.assign);
    assert!(auto.exec_calls <= pinned.exec_calls, "auto plan should use fewer calls");
}

/// Engine selection through the eval dispatcher (what benches/CLI use).
#[test]
fn eval_dispatch_all_engines() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = eval::paper_dataset(3, 12_000);
    let mut sses = Vec::new();
    for engine in [
        Engine::Serial,
        Engine::Threads,
        Engine::Elkan,
        Engine::Hamerly,
        Engine::Shared,
        Engine::Offload,
    ] {
        let t = eval::run_engine(engine, &ds, 4, 4, 42).unwrap();
        assert!(t.converged, "{engine} did not converge");
        sses.push(t.sse);
    }
    // exact algorithms: all SSE equal within f32 slack
    let base = sses[0];
    for (i, s) in sses.iter().enumerate() {
        assert!((s - base).abs() / base < 1e-3, "engine {i} sse {s} vs {base}");
    }
}

/// Convergence-parameter plumbing: tol and max_iters are honored
/// through the AOT engines.
#[test]
fn convergence_controls() {
    if !artifacts_ready() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let ds = MixtureSpec::paper_3d(4).generate(10_000, 13);
    // max_iters = 2: must stop early, not converged
    let c = RunConfig { max_iters: 2, tol: 0.0, ..cfg(4) };
    let r = offload::run(&ds, &c).unwrap();
    assert_eq!(r.result.iterations, 2);
    assert!(!r.result.converged);
    // huge tol: one iteration, converged
    let c = RunConfig { tol: 1e12, ..cfg(4) };
    let r = shared::run(&ds, &c, 2).unwrap();
    assert_eq!(r.result.iterations, 1);
    assert!(r.result.converged);
}
