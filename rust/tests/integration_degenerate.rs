//! Integration: degenerate inputs every engine must survive without
//! panicking, producing NaNs, or emitting out-of-range assignments.
//!
//! Two adversarial datasets:
//!   - all points identical — every centroid collapses onto one point;
//!     k−1 clusters go empty on iteration one and *stay* empty (the
//!     keep-centroid policy), which the per-iteration `empty_events`
//!     counters must record;
//!   - k exceeds the number of distinct points — 3 distinct rows tiled
//!     to n = 300 with k = 8 can fill at most 3 clusters.
//!
//! The contract is the same for every engine (serial, threads
//! static+steal, elkan, hamerly, minibatch, bisecting, oocore,
//! dist static+elastic over loopback): finite SSE, finite centroids,
//! one in-range assignment per row, and termination.

use std::time::Duration;

use parakmeans::cluster::LoopbackCluster;
use parakmeans::config::{DistSched, SchedMode};
use parakmeans::data::source::MemorySource;
use parakmeans::data::Dataset;
use parakmeans::kmeans::dist::{self, DistOpts};
use parakmeans::kmeans::streaming::{self, StreamOpts};
use parakmeans::kmeans::{
    bisecting, elkan, hamerly, minibatch, parallel, serial, KmeansConfig, KmeansResult,
};

/// n rows of the identical point (0.5, −1.25, 3.0).
fn identical_points(n: usize) -> Dataset {
    let row = [0.5f32, -1.25, 3.0];
    let mut data = Vec::with_capacity(n * row.len());
    for _ in 0..n {
        data.extend_from_slice(&row);
    }
    Dataset::from_vec(data, row.len()).unwrap()
}

/// 3 distinct rows tiled to n — at most 3 nonempty clusters, ever.
fn few_distinct_points(n: usize) -> Dataset {
    let rows = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0]];
    let mut data = Vec::with_capacity(n * 2);
    for i in 0..n {
        data.extend_from_slice(&rows[i % rows.len()]);
    }
    Dataset::from_vec(data, 2).unwrap()
}

fn cfg(k: usize) -> KmeansConfig {
    KmeansConfig::new(k).with_seed(17).with_max_iters(25)
}

/// The degenerate-input contract: the run terminated with finite,
/// in-range output. Deliberately says nothing about *which* clusters
/// survive — that is engine-specific; not panicking is the contract.
fn assert_valid(r: &KmeansResult, n: usize, k: usize, what: &str) {
    assert_eq!(r.assign.len(), n, "{what}: one assignment per row");
    assert!(
        r.assign.iter().all(|&a| a >= 0 && (a as usize) < k),
        "{what}: assignment out of [0, {k})"
    );
    assert!(r.sse.is_finite(), "{what}: sse {} not finite", r.sse);
    assert!(
        r.centroids.iter().all(|c| c.is_finite()),
        "{what}: non-finite centroid"
    );
    assert!(r.iterations >= 1, "{what}: ran zero iterations");
}

fn dist_opts(sched: DistSched) -> DistOpts {
    DistOpts {
        connect_timeout: Duration::from_secs(5),
        io_timeout: Duration::from_secs(10),
        sched,
        retry: 2,
    }
}

/// Run every resident engine over `ds` with `k` clusters and apply the
/// contract. Returns the serial result for case-specific assertions.
fn sweep_resident(ds: &Dataset, k: usize, tag: &str) -> KmeansResult {
    let n = ds.len();
    let c = cfg(k);

    let r = serial::run(ds, &c);
    assert_valid(&r, n, k, &format!("{tag}/serial"));

    for (mode, name) in [(SchedMode::Static, "static"), (SchedMode::Steal, "steal")] {
        let t = parallel::run_sched(ds, &c, 3, parallel::MergeMode::Leader, mode);
        assert_valid(&t, n, k, &format!("{tag}/threads-{name}"));
    }

    let e = elkan::run_threads(ds, &c, 3, SchedMode::Steal);
    assert_valid(&e, n, k, &format!("{tag}/elkan"));

    let h = hamerly::run_threads(ds, &c, 3, SchedMode::Steal);
    assert_valid(&h, n, k, &format!("{tag}/hamerly"));

    let m = minibatch::run(ds, &c, 64);
    assert_valid(&m, n, k, &format!("{tag}/minibatch"));

    let b = bisecting::run(ds, &c, 2);
    assert_valid(&b, n, k, &format!("{tag}/bisecting"));

    let src = MemorySource::new(ds);
    let o = streaming::run(&src, &c, &StreamOpts { shards: 3, chunk_rows: 64 }).unwrap();
    assert_valid(&o, n, k, &format!("{tag}/oocore"));

    r
}

fn sweep_dist(ds: &Dataset, k: usize, tag: &str) {
    let n = ds.len();
    let c = cfg(k);

    let cluster = LoopbackCluster::spawn_dataset(ds, 2, 64).unwrap();
    let run = dist::run(&cluster.addrs, &c, &dist_opts(DistSched::Static)).unwrap();
    cluster.join().unwrap();
    assert_valid(&run.result, n, k, &format!("{tag}/dist-static"));

    let cluster = LoopbackCluster::spawn_replicated(ds, 2, 64).unwrap();
    let run = dist::run(&cluster.addrs, &c, &dist_opts(DistSched::Elastic)).unwrap();
    cluster.join().unwrap();
    assert_valid(&run.result, n, k, &format!("{tag}/dist-elastic"));
}

#[test]
fn identical_points_every_resident_engine() {
    let ds = identical_points(400);
    let serial = sweep_resident(&ds, 4, "identical");

    // with every point equal, the surviving cluster absorbs everything:
    // sse is exactly 0 and k−1 clusters sat empty each iteration — the
    // empty-cluster telemetry must have seen them
    assert_eq!(serial.sse, 0.0, "identical points: sse must be exactly 0");
    assert!(
        serial.empty_total() > 0,
        "identical points: empty-cluster events went unrecorded"
    );
}

#[test]
fn identical_points_dist_engines() {
    let ds = identical_points(400);
    sweep_dist(&ds, 4, "identical");
}

#[test]
fn k_exceeds_distinct_points_every_resident_engine() {
    let ds = few_distinct_points(300);
    let serial = sweep_resident(&ds, 8, "few-distinct");

    // at most 3 clusters can own points; a perfect run puts each
    // distinct row in its own cluster for sse 0, but the contract only
    // demands the unused clusters didn't corrupt the output
    let used: std::collections::BTreeSet<i32> = serial.assign.iter().copied().collect();
    assert!(used.len() <= 3, "few-distinct: {} clusters own points", used.len());
}

#[test]
fn k_exceeds_distinct_points_dist_engines() {
    let ds = few_distinct_points(300);
    sweep_dist(&ds, 8, "few-distinct");
}

#[test]
fn single_row_dataset_serial_and_threads() {
    // the harshest shrink: n = 1, k = 1 — one row, one cluster
    let ds = Dataset::from_vec(vec![2.0, 3.0, 4.0], 3).unwrap();
    let c = cfg(1);
    let r = serial::run(&ds, &c);
    assert_valid(&r, 1, 1, "single-row/serial");
    assert_eq!(r.sse, 0.0);
    let t = parallel::run_sched(&ds, &c, 3, parallel::MergeMode::Leader, SchedMode::Steal);
    assert_valid(&t, 1, 1, "single-row/threads");
}
