//! Fuzz: the artifact codecs (`.pkc` checkpoints, `.pkm` models) are
//! total functions over arbitrary bytes — every decode of corrupt
//! input is a *typed* error (`Error::Ckpt` / `Error::Data`), never a
//! panic, hang, or attacker-sized allocation (DESIGN.md §14).
//!
//! Adversarial coverage per run, all deterministic (seeds derive from
//! property names; `PARAKM_PROP_SEED` overrides):
//!   - truncation at EVERY byte boundary of valid encodings
//!   - random bit flips / inserts / deletes / overwrites (`Gen::mutate`)
//!   - pure byte soup, with and without a valid magic+version prefix
//!   - forged section lengths (0xFFFF_FFFF) and wrong format versions
//! Totals well over 5,000 hostile inputs; the count is asserted so a
//! refactor cannot silently shrink the net.
//!
//! Round-trip: `encode_ckpt` is deterministic and bit-exact (NaN
//! history entries included), so equality is checked on the *bytes* —
//! `encode(decode(b)) == b` — which is stricter than `PartialEq` on
//! the structs (NaN != NaN) and proves the codec loses nothing.

use parakmeans::data::io::{self, Model};
use parakmeans::error::Error;
use parakmeans::kmeans::ckpt::{Bounds, CkptState, Fingerprint};
use parakmeans::testutil::prop::{self, Gen};

fn gen_fingerprint(g: &mut Gen) -> Fingerprint {
    Fingerprint {
        engine: (*g.choice(&["serial", "threads", "elkan", "hamerly", "oocore", "dist"])).to_string(),
        seed: g.u64(),
        k: g.usize_in(1, 16) as u32,
        distance: (*g.choice(&["exact", "dot"])).to_string(),
        sched: (*g.choice(&["none", "static", "steal", "elastic"])).to_string(),
        n: g.usize_in(1, 100_000) as u64,
        d: g.usize_in(1, 8) as u32,
    }
}

/// A structurally consistent snapshot — what a real engine would save.
/// `with_bounds` adds an Elkan- or Hamerly-shaped bounds section.
fn gen_state(g: &mut Gen, with_bounds: bool) -> CkptState {
    let fp = gen_fingerprint(g);
    let (k, d) = (fp.k as usize, fp.d as usize);
    let n = g.usize_in(1, 48);
    let iter = g.usize_in(1, 10) as u64;
    let kd = k * d;
    let mut history: Vec<(f64, f64)> =
        (0..iter).map(|_| (g.f64_in(0.0, 1e9), g.f64_in(0.0, 16.0))).collect();
    if g.bool() {
        // bounds engines store NaN sse until the lazy fill — the codec
        // must round-trip the exact NaN bit pattern
        if let Some(h) = history.last_mut() {
            h.0 = f64::NAN;
        }
    }
    let lower_per_point = if g.bool() { k } else { 1 };
    let bounds = if with_bounds {
        Some(Bounds {
            assign: (0..n).map(|_| g.usize_in(0, k - 1) as i32).collect(),
            upper: (0..n).map(|_| g.f32_in(0.0, 64.0)).collect(),
            lower: (0..n * lower_per_point).map(|_| g.f32_in(0.0, 64.0)).collect(),
            sums: (0..kd).map(|_| g.f64_in(-1e3, 1e3)).collect(),
            counts: (0..k).map(|_| g.usize_in(0, 1000) as u64).collect(),
            prune_seed_computed: g.u64(),
            prune_per_iter: (0..iter).map(|_| (g.u64() % 4096, g.u64() % 4096)).collect(),
        })
    } else {
        None
    };
    CkptState {
        fingerprint: fp,
        iteration: iter,
        converged: g.bool(),
        centroids: (0..kd).map(|_| g.f32_in(-16.0, 16.0)).collect(),
        prev_centroids: (0..kd).map(|_| g.f32_in(-16.0, 16.0)).collect(),
        history,
        empty_events: (0..iter).map(|_| g.usize_in(0, 4) as u64).collect(),
        bounds,
    }
}

fn gen_model(g: &mut Gen) -> Model {
    let k = g.usize_in(1, 16);
    let dim = g.usize_in(1, 8);
    Model {
        k,
        dim,
        seed: g.u64(),
        engine: (*g.choice(&["serial", "threads", "dist"])).to_string(),
        iterations: g.usize_in(1, 500),
        sse: g.f64_in(0.0, 1e12),
        centroids: (0..k * dim).map(|_| g.f32_in(-16.0, 16.0)).collect(),
    }
}

// ---- .pkc checkpoints --------------------------------------------------

#[test]
fn ckpt_roundtrip_is_bit_exact() {
    prop::check("ckpt_roundtrip", 600, |g| {
        let with_bounds = g.bool();
        let state = gen_state(g, with_bounds);
        let bytes = io::encode_ckpt(&state);
        let decoded = match io::decode_ckpt(&bytes) {
            Ok(s) => s,
            Err(e) => return Err(format!("valid encoding failed to decode: {e}")),
        };
        prop::ensure(
            io::encode_ckpt(&decoded) == bytes,
            "re-encode of decoded state diverged from original bytes",
        )?;
        prop::ensure(decoded.bounds.is_some() == with_bounds, "bounds presence lost")?;
        prop::ensure(decoded.iteration == state.iteration, "iteration lost")
    });
}

#[test]
fn ckpt_truncation_at_every_byte_is_typed() {
    // every strict prefix of a valid .pkc must fail typed: the final
    // section's CRC is always missing, so no prefix can decode
    let mut cases = 0usize;
    for seed in 0..6u64 {
        let mut g = Gen::new(seed);
        let state = gen_state(&mut g, seed % 2 == 0);
        let bytes = io::encode_ckpt(&state);
        for len in 0..bytes.len() {
            match io::decode_ckpt(&bytes[..len]) {
                Err(Error::Ckpt(_)) => {}
                Ok(_) => panic!("truncation to {len}/{} bytes decoded", bytes.len()),
                Err(e) => panic!("truncation to {len} bytes gave non-Ckpt error: {e:?}"),
            }
            cases += 1;
        }
    }
    assert!(cases >= 1500, "truncation sweep shrank to {cases} cases");
}

#[test]
fn ckpt_mutations_never_panic() {
    prop::check("ckpt_mutations", 2000, |g| {
        let with_bounds = g.bool();
        let state = gen_state(g, with_bounds);
        let mut bytes = io::encode_ckpt(&state);
        let edits = g.usize_in(1, 12);
        g.mutate(&mut bytes, edits);
        // decode must be total: Ok (mutation was benign or reverted) or
        // a typed checkpoint error — anything else fails the property
        match io::decode_ckpt(&bytes) {
            Ok(_) | Err(Error::Ckpt(_)) => Ok(()),
            Err(e) => Err(format!("mutated .pkc gave non-Ckpt error: {e:?}")),
        }
    });
}

#[test]
fn ckpt_byte_soup_is_typed() {
    prop::check("ckpt_soup", 1000, |g| {
        let n = g.usize_in(0, 512);
        let soup = g.bytes(n);
        match io::decode_ckpt(&soup) {
            Ok(_) => Err("byte soup decoded as a checkpoint".into()),
            Err(Error::Ckpt(_)) => Ok(()),
            Err(e) => Err(format!("soup gave non-Ckpt error: {e:?}")),
        }
    });
}

#[test]
fn ckpt_soup_behind_valid_header_is_typed() {
    // correct magic + version, then garbage: the section framing (len
    // guard + CRC) must reject it without allocating the forged length
    prop::check("ckpt_header_soup", 800, |g| {
        let state = gen_state(g, false);
        let valid = io::encode_ckpt(&state);
        let mut bytes = valid[..12].to_vec(); // magic + version
        let tail = g.usize_in(0, 256);
        bytes.extend_from_slice(&g.bytes(tail));
        match io::decode_ckpt(&bytes) {
            Ok(_) => Err("garbage behind a valid header decoded".into()),
            Err(Error::Ckpt(_)) => Ok(()),
            Err(e) => Err(format!("non-Ckpt error: {e:?}")),
        }
    });
}

#[test]
fn ckpt_forged_section_length_is_typed_not_oom() {
    let mut g = Gen::new(7);
    let state = gen_state(&mut g, true);
    let mut bytes = io::encode_ckpt(&state);
    // first section length lives right after magic(8) + version(4)
    bytes[12..16].copy_from_slice(&0xFFFF_FFFFu32.to_le_bytes());
    match io::decode_ckpt(&bytes) {
        Err(Error::Ckpt(_)) => {}
        other => panic!("forged 4 GiB section length: {other:?}"),
    }
}

#[test]
fn ckpt_wrong_version_is_typed_and_named() {
    let mut g = Gen::new(11);
    let state = gen_state(&mut g, false);
    let mut bytes = io::encode_ckpt(&state);
    bytes[8..12].copy_from_slice(&999u32.to_le_bytes());
    let err = io::decode_ckpt(&bytes).unwrap_err();
    assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
    assert!(err.to_string().contains("version 999"), "{err}");
}

#[test]
fn ckpt_wrong_magic_is_typed() {
    let mut g = Gen::new(13);
    let state = gen_state(&mut g, false);
    let mut bytes = io::encode_ckpt(&state);
    bytes[0] ^= 0x20;
    let err = io::decode_ckpt(&bytes).unwrap_err();
    assert!(matches!(err, Error::Ckpt(_)), "{err:?}");
    assert!(err.to_string().contains("magic"), "{err}");
}

// ---- .pkm models -------------------------------------------------------

#[test]
fn model_truncation_sweep_typed_except_legacy_point() {
    // one legal truncation exists: dropping exactly the 4-byte CRC
    // trailer is the legacy CRC-less layout, which still decodes (and
    // bumps the artifact-warnings counter). Every other prefix fails.
    let mut cases = 0usize;
    for seed in 0..4u64 {
        let mut g = Gen::new(seed);
        let model = gen_model(&mut g);
        let bytes = io::encode_model(&model).unwrap();
        let legacy_len = bytes.len() - 4;
        for len in 0..bytes.len() {
            match io::decode_model(&bytes[..len]) {
                Ok(m) if len == legacy_len => {
                    assert_eq!(m.k, model.k, "legacy decode mangled k");
                }
                Ok(_) => panic!("truncation to {len}/{} bytes decoded", bytes.len()),
                Err(Error::Data(_)) => {
                    assert_ne!(len, legacy_len, "legacy CRC-less layout must still decode");
                }
                Err(e) => panic!("truncation to {len} bytes gave non-Data error: {e:?}"),
            }
            cases += 1;
        }
    }
    assert!(cases >= 150, "truncation sweep shrank to {cases} cases");
}

#[test]
fn model_mutations_never_panic() {
    prop::check("model_mutations", 2000, |g| {
        let model = gen_model(g);
        let mut bytes = io::encode_model(&model).unwrap();
        let edits = g.usize_in(1, 12);
        g.mutate(&mut bytes, edits);
        match io::decode_model(&bytes) {
            Ok(_) | Err(Error::Data(_)) => Ok(()),
            Err(e) => Err(format!("mutated .pkm gave non-Data error: {e:?}")),
        }
    });
}

#[test]
fn model_byte_soup_is_typed() {
    prop::check("model_soup", 800, |g| {
        let n = g.usize_in(0, 512);
        let soup = g.bytes(n);
        match io::decode_model(&soup) {
            Ok(_) => Err("byte soup decoded as a model".into()),
            Err(Error::Data(_)) => Ok(()),
            Err(e) => Err(format!("soup gave non-Data error: {e:?}")),
        }
    });
}
