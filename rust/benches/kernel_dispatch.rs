//! Kernel-dispatch bench: scalar vs SIMD tier per (n, d, k) — the
//! Table 2/3 speedup analysis extended one level down, to the fused
//! assign/accumulate kernel both the OpenMP-model and OpenACC-model
//! engines execute per iteration.
//!
//!     cargo bench --bench kernel_dispatch
//!
//! Knobs (also used by CI bench-smoke):
//!   PARAKM_BENCH_N        rows per case (default 200000)
//!   PARAKM_BENCH_WARMUP / PARAKM_BENCH_REPEATS / PARAKM_BENCH_CAP_SECS
//!
//! Prints one `BENCH` row per (tier, n, d, k) plus a `SPEEDUP` row per
//! (n, d, k) with SIMD-vs-scalar ratio. Also cross-checks (exactly, no
//! timing assertions) that both tiers produce identical assignments.

use parakmeans::linalg::kernel::{self, KernelTier};
use parakmeans::rng::Pcg64;
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn run_tier(
    rows: &[f32],
    d: usize,
    mu: &[f32],
    k: usize,
    tier: KernelTier,
) -> (Vec<i32>, f64) {
    let n = rows.len() / d;
    let mut assign = vec![0i32; n];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut sse = 0.0f64;
    kernel::assign_accumulate(rows, d, mu, k, &mut assign, &mut sums, &mut counts, &mut sse, tier);
    (assign, sse)
}

fn main() {
    let opts = BenchOpts::from_env();
    let n = opts.n;
    let simd = kernel::detect();
    println!("== kernel dispatch bench (n={n}) ==");
    println!("detected tier: {simd}");

    for d in [2usize, 3, 4, 8, 16, 17, 32] {
        let mut rng = Pcg64::new(0xD15 + d as u64, 0);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 20.0).collect();
        for k in [4usize, 8, 16] {
            let mu: Vec<f32> = (0..k * d).map(|_| rng.next_f32() * 20.0).collect();

            // correctness cross-check first (cheap, exact)
            let (a_scalar, sse_scalar) = run_tier(&rows, d, &mu, k, KernelTier::Scalar);
            if simd != KernelTier::Scalar {
                let (a_simd, sse_simd) = run_tier(&rows, d, &mu, k, simd);
                assert_eq!(a_scalar, a_simd, "tier mismatch at d={d} k={k}");
                // same tolerance the property tests grant: <= 1 ulp
                let ulps = (sse_scalar.to_bits() as i64 - sse_simd.to_bits() as i64).abs();
                assert!(ulps <= 1, "sse drift {ulps} ulps at d={d} k={k}");
            }

            let s_scalar = run_case(&format!("scalar  n={n} d={d:<2} k={k:<2}"), &opts, || {
                run_tier(&rows, d, &mu, k, KernelTier::Scalar)
            });
            report(&s_scalar);
            if simd != KernelTier::Scalar {
                let s_simd = run_case(&format!("{simd:<7} n={n} d={d:<2} k={k:<2}"), &opts, || {
                    run_tier(&rows, d, &mu, k, simd)
                });
                report(&s_simd);
                println!(
                    "SPEEDUP n={n} d={d:<2} k={k:<2}  {simd}/scalar = {:.2}x",
                    s_scalar.median() / s_simd.median()
                );
            }
        }
    }
}
