//! Bench F7–F10 — regenerates paper Figures 7/8 (speedup ψ(n,p)) and
//! 9/10 (efficiency ε(n,p)) for the 3D and 2D families. Writes CSV +
//! SVG to results/figures/ and prints the series.
//!
//!     PARAKM_SCALE=full cargo bench --bench figures_speedup_efficiency

use parakmeans::eval::{figures, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts { repeats: 1, ..BenchOpts::from_env() };
    println!("== FIGURES 7-10 bench (scale {scale:?}) ==");
    let s3 = run_case("speedup+efficiency 3D (figs 7/9)", &opts, || {
        figures::speedup_efficiency(3, scale).expect("3d")
    });
    report(&s3);
    let s2 = run_case("speedup+efficiency 2D (figs 8/10)", &opts, || {
        figures::speedup_efficiency(2, scale).expect("2d")
    });
    report(&s2);
}
