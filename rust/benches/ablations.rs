//! Bench A1–A3 — the design-choice ablations DESIGN.md §5 calls out:
//! A1 chunk size, A2 merge policy (leader vs critical), A3 algorithm /
//! init matrix (Lloyd vs Elkan vs Hamerly vs mini-batch; random vs
//! k-means++).
//!
//!     PARAKM_SCALE=full cargo bench --bench ablations

use std::sync::mpsc;

use parakmeans::data::gmm::MixtureSpec;
use parakmeans::eval::{ablations, Scale};
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::serve::batcher::{Batcher, Job};
use parakmeans::serve::{BatcherConfig, Request};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts { repeats: 1, ..BenchOpts::from_env() };
    println!("== ABLATIONS bench (scale {scale:?}) ==");
    let a1 = run_case("A1 chunk size", &opts, || {
        ablations::chunk_size(scale).expect("a1")
    });
    report(&a1);
    let a2 = run_case("A2 merge policy", &opts, || {
        ablations::merge_policy(scale).expect("a2")
    });
    report(&a2);
    let a3 = run_case("A3 algorithms/init", &opts, || {
        ablations::algorithms(scale).expect("a3")
    });
    report(&a3);
    serve_batching_ablation();
}

/// A-serve — batching level vs device-call efficiency: the same 256
/// requests × 32 points flushed in groups of g requests per batch.
/// More batching = fewer padded `assign` calls = higher points/s;
/// the latency side of the trade-off lives in `examples/serving_load`.
fn serve_batching_ablation() {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built; skipping A-serve)");
        return;
    }
    let ds = MixtureSpec::paper_3d(4).generate(20_000, 3);
    let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(1));
    let opts = BenchOpts { repeats: 3, ..BenchOpts::from_env() };
    let requests = 256usize;
    let points = 32usize;
    for group in [1usize, 8, 64, 128] {
        let mut b = Batcher::new(dir, model.centroids.clone(), 3, 4, BatcherConfig::default())
            .expect("batcher");
        let mk_jobs = |lo: usize, hi: usize| -> (Vec<Job>, Vec<mpsc::Receiver<_>>) {
            let mut jobs = Vec::new();
            let mut rxs = Vec::new();
            for r in lo..hi {
                let pts: Vec<Vec<f64>> = (0..points)
                    .map(|i| {
                        ds.point((r * points + i) % ds.len()).iter().map(|&v| v as f64).collect()
                    })
                    .collect();
                let (tx, rx) = mpsc::channel();
                jobs.push(Job { request: Request { id: r as u64, points: pts }, reply: tx });
                rxs.push(rx);
            }
            (jobs, rxs)
        };
        let s = run_case(&format!("A-serve batch-group={group}"), &opts, || {
            let mut done = 0;
            while done < requests {
                let hi = (done + group).min(requests);
                let (jobs, rxs) = mk_jobs(done, hi);
                b.flush(jobs);
                for rx in rxs {
                    rx.recv().expect("reply");
                }
                done = hi;
            }
        });
        report(&s);
        println!(
            "         -> {:.0} points/s, {} device calls",
            (requests * points) as f64 / s.median(),
            b.stats.device_calls
        );
    }
}
