//! Sustained serving-load bench: drive both serve loops with the same
//! deterministic request stream and record ns/request plus the p50/p99
//! latency tail into `results/bench.json` — the gate for the
//! event-driven serve path (DESIGN.md §13).
//!
//! Beyond timing, this is also a cross-check: the per-client response
//! streams from `--serve-loop poll` (tape parser, reactor) must be
//! byte-identical to `--serve-loop threads` (legacy parser, blocking
//! IO). A divergence fails the bench, so CI's bench-smoke job enforces
//! the equivalence contract under sustained load, not just on the unit
//! corpus.
//!
//!     cargo bench --offline --bench serving_load
//!
//! Honors PARAKM_BENCH_N (scales requests per client) and the other
//! PARAKM_BENCH_* knobs via `BenchOpts::from_env`.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::path::Path;
use std::time::Instant;

use parakmeans::data::gmm::MixtureSpec;
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::linalg::kernel;
use parakmeans::rng::Pcg64;
use parakmeans::serve::{serve, Response, ServeConfig, ServeLoop};
use parakmeans::util::bench::{self, BenchOpts, Sample};

const CLIENTS: usize = 4;
const POINTS_PER_REQUEST: usize = 32;

/// Deterministic request line for (client, request) — identical across
/// loop modes so the response cross-check is exact.
fn request_line(client: usize, req: usize, per_client: usize) -> String {
    let mut rng = Pcg64::new(client as u64, 0x10AD);
    // burn the generator to this request's offset so lines depend only
    // on (client, req), not on connection pacing
    for _ in 0..req * POINTS_PER_REQUEST * 3 {
        rng.next_f32();
    }
    let pts: Vec<String> = (0..POINTS_PER_REQUEST)
        .map(|_| {
            format!(
                "[{}, {}, {}]",
                rng.next_f32() * 30.0,
                rng.next_f32() * 30.0,
                rng.next_f32() * 30.0
            )
        })
        .collect();
    format!(r#"{{"id": {}, "points": [{}]}}"#, client * per_client + req, pts.join(", "))
}

/// Drive one serve loop; returns per-request latencies (seconds) and
/// each client's in-order response lines.
fn drive(mode: ServeLoop, centroids: &[f32], per_client: usize) -> (Vec<f64>, Vec<Vec<String>>) {
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        // a never-existing artifacts dir pins the in-crate native
        // runtime: portable and deterministic across bench hosts
        artifacts_dir: std::env::temp_dir().join("parakm_serving_load/no_artifacts_here"),
        loop_mode: mode,
        ..Default::default()
    };
    let server = serve(cfg, centroids.to_vec(), 3, 4).expect("serve");
    let addr = server.local_addr;
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || {
                let mut conn = TcpStream::connect(addr).expect("connect");
                conn.set_nodelay(true).expect("nodelay");
                let mut reader = BufReader::new(conn.try_clone().expect("clone"));
                let mut latencies = Vec::with_capacity(per_client);
                let mut responses = Vec::with_capacity(per_client);
                for r in 0..per_client {
                    let line = request_line(c, r, per_client);
                    let t = Instant::now();
                    writeln!(conn, "{line}").expect("send");
                    let mut resp = String::new();
                    reader.read_line(&mut resp).expect("recv");
                    latencies.push(t.elapsed().as_secs_f64());
                    let resp = resp.trim_end().to_string();
                    match Response::parse(&resp).expect("parse response") {
                        Response::Ok { clusters, .. } => {
                            assert_eq!(clusters.len(), POINTS_PER_REQUEST, "short reply");
                        }
                        Response::Err { error, .. } => panic!("server error: {error}"),
                    }
                    responses.push(resp);
                }
                (latencies, responses)
            })
        })
        .collect();
    let mut latencies = Vec::new();
    let mut responses = Vec::new();
    for h in handles {
        let (lat, resp) = h.join().expect("client panicked");
        latencies.extend(lat);
        responses.push(resp);
    }
    server.shutdown();
    (latencies, responses)
}

fn pct(sorted: &[f64], q: f64) -> f64 {
    sorted[(q * (sorted.len() - 1) as f64) as usize]
}

fn main() {
    let opts = BenchOpts::from_env();
    // scale sustained load with the bench-size knob, but keep enough
    // requests for a meaningful p99 even in CI's shrunken runs
    let per_client = (opts.n / 4_000).clamp(50, 500);
    let total = CLIENTS * per_client;

    let ds = MixtureSpec::paper_3d(4).generate(20_000, 42);
    let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(7));
    let tier = kernel::active_tier().to_string();

    let mut modes = vec![ServeLoop::Threads];
    if cfg!(unix) {
        modes.push(ServeLoop::Poll);
    }

    let mut rows = Vec::new();
    let mut streams: Vec<(ServeLoop, Vec<Vec<String>>)> = Vec::new();
    for &mode in &modes {
        let engine = format!("serve-{mode}");
        let (mut latencies, responses) = drive(mode, &model.centroids, per_client);
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = latencies.iter().sum::<f64>() / latencies.len() as f64;
        let ns_per_request = mean * 1e9;
        let p50_us = pct(&latencies, 0.50) * 1e6;
        let p99_us = pct(&latencies, 0.99) * 1e6;
        bench::report(&Sample {
            label: format!("{engine} C={CLIENTS} R={per_client} P={POINTS_PER_REQUEST} [{tier}]"),
            runs: latencies,
        });
        println!(
            "  {engine}: {total} requests, {ns_per_request:.0} ns/request, p50 {p50_us:.1} µs, \
             p99 {p99_us:.1} µs"
        );
        rows.push(bench::bench_json_serve_row(
            "serving_load",
            &engine,
            &tier,
            total,
            POINTS_PER_REQUEST,
            ns_per_request,
            p50_us,
            p99_us,
        ));
        streams.push((mode, responses));
    }

    // the cross-loop equivalence gate: identical request streams must
    // yield byte-identical per-client response streams
    if streams.len() == 2 {
        let (m0, s0) = &streams[0];
        let (m1, s1) = &streams[1];
        assert_eq!(
            s0, s1,
            "response streams diverge between --serve-loop {m0} and --serve-loop {m1}"
        );
        println!("  cross-check: {m0} ≡ {m1} on {total} responses");
    }

    bench::append_bench_json(Path::new("results/bench.json"), rows)
        .expect("write results/bench.json");
    println!("serving_load OK");
}
