//! Bench F11–F12 — regenerates paper Figures 11/12: time vs dataset
//! scale for serial / shared(p=8) / offload, 3D and 2D families.
//!
//!     PARAKM_SCALE=full cargo bench --bench figures_scaling

use parakmeans::eval::{figures, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts { repeats: 1, ..BenchOpts::from_env() };
    println!("== FIGURES 11-12 bench (scale {scale:?}) ==");
    let s3 = run_case("time-vs-scaling 3D (fig 11)", &opts, || {
        figures::time_vs_scaling(3, scale).expect("3d")
    });
    report(&s3);
    let s2 = run_case("time-vs-scaling 2D (fig 12)", &opts, || {
        figures::time_vs_scaling(2, scale).expect("2d")
    });
    report(&s2);
}
