//! Bench T4 — regenerates paper Table 4: 2D dataset size vs
//! offload-engine time (K = 8).
//!
//!     PARAKM_SCALE=full cargo bench --bench table4_offload_2d

use parakmeans::eval::{tables, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts::from_env();
    println!("== TABLE 4 bench (scale {scale:?}) ==");
    let sample = run_case("table4(all cells)", &opts, || {
        tables::table4(scale).expect("table4")
    });
    report(&sample);
}
