//! Bench T2 — regenerates paper Table 2: 2D dataset, shared-memory
//! engine time vs threads p ∈ {2, 4, 8, 16} (K = 8).
//!
//!     PARAKM_SCALE=full cargo bench --bench table2_shared_2d

use parakmeans::eval::{tables, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts::from_env();
    println!("== TABLE 2 bench (scale {scale:?}) ==");
    let sample = run_case("table2(all cells)", &opts, || {
        tables::table2(scale).expect("table2")
    });
    report(&sample);
}
