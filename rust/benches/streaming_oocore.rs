//! Out-of-core streaming sweep: chunk size × shard count on the
//! paper's 3D GMM family, file-backed — the memory/parallelism trade
//! of `kmeans::streaming` quantified, with the determinism contract
//! cross-checked exactly on every cell.
//!
//!     cargo bench --bench streaming_oocore
//!
//! Knobs (also used by CI bench-smoke):
//!   PARAKM_BENCH_N        dataset rows (default 200000)
//!   PARAKM_BENCH_WARMUP / PARAKM_BENCH_REPEATS / PARAKM_BENCH_CAP_SECS
//!
//! Every cell is cross-checked exactly against its in-memory twin (no
//! timing assertions): shards = 1 must be bit-identical to the serial
//! engine, shards = S to the threaded engine at p = S — the two
//! guarantees of the chunked-accumulation contract (DESIGN.md §4).
//! Writes `results/tables/oocore.csv` (columns: shards, chunk_rows,
//! buffer_bytes, secs, iters, sse) for `eval::report`.

use parakmeans::data::source::FileSource;
use parakmeans::data::{gmm::workloads, io};
use parakmeans::eval;
use parakmeans::kmeans::streaming::{run_from, StreamOpts};
use parakmeans::kmeans::{self, init, KmeansConfig};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::util::bench::{report, run_case, BenchOpts};
use parakmeans::util::csv;

fn main() {
    let opts = BenchOpts::from_env();
    let n = opts.n;
    let k = workloads::K_3D;
    println!("== streaming oocore bench (3D, n={n}, K={k}) ==");

    // dataset on disk: the engine under test streams it; the serial
    // reference gets the same rows resident
    let ds = eval::paper_dataset(3, n);
    let path = std::env::temp_dir().join(format!("parakm_oocore_bench_{n}.pkd"));
    io::write_binary(&path, &ds).expect("write bench dataset");
    let src = FileSource::open(&path).expect("open bench dataset");

    let cfg = KmeansConfig::new(k).with_seed(42);
    let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
    let reference = kmeans::serial::run_from(&ds, &cfg, &mu0);
    println!(
        "serial reference: {} iters (converged: {}), sse {:.6e}",
        reference.iterations, reference.converged, reference.sse
    );

    let payload_bytes = n * 3 * 4;
    let mut rows: Vec<Vec<f64>> = Vec::new();
    for shards in [1usize, 2, 4, 8] {
        // the in-memory twin of this shard count: serial at 1 shard,
        // threads(p = shards) otherwise — bit-identical by contract
        let twin = if shards == 1 {
            reference.clone()
        } else {
            kmeans::parallel::run_from(&ds, &cfg, shards, kmeans::parallel::MergeMode::Leader, &mu0)
        };
        for chunk_rows in [4096usize, 16384, 65536] {
            let so = StreamOpts { shards, chunk_rows };
            let buffer = so.buffer_bytes(3);

            // determinism cross-check (exact, once per cell)
            let r = run_from(&src, &cfg, &so, &mu0).expect("oocore run");
            assert_bit_identical(&r, &twin, &format!("s={shards} c={chunk_rows}"));

            let label = format!(
                "oocore n={n} shards={shards} chunk={chunk_rows:<6} buf={:>7}B",
                buffer
            );
            let s = run_case(&label, &opts, || run_from(&src, &cfg, &so, &mu0).expect("run"));
            report(&s);
            println!(
                "         -> residency {:.2}% of payload ({buffer} / {payload_bytes} B)",
                100.0 * buffer as f64 / payload_bytes as f64
            );
            rows.push(vec![
                shards as f64,
                chunk_rows as f64,
                buffer as f64,
                s.median(),
                r.iterations as f64,
                r.sse,
            ]);
        }
    }

    let out = eval::results_dir().join("tables/oocore.csv");
    csv::write_table(&out, &["shards", "chunk_rows", "buffer_bytes", "secs", "iters", "sse"], &rows)
        .expect("write oocore.csv");
    println!("wrote {}", out.display());
    let _ = std::fs::remove_file(&path);
}
