//! Bench F1–F6 — regenerates paper Figures 1–6: cluster scatter plots
//! (serial vs parallel) for 3D 1M/400k (K=4) and 2D 500k (K=11), with
//! the paper's visual "similar clustering" claim checked as ARI.
//!
//!     PARAKM_SCALE=full cargo bench --bench figures_clusters

use parakmeans::eval::{figures, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts { repeats: 1, ..BenchOpts::from_env() };
    println!("== FIGURES 1-6 bench (scale {scale:?}) ==");
    let s = run_case("cluster figures (1-6)", &opts, || {
        let figs = figures::cluster_figures(scale).expect("figures");
        for f in &figs {
            assert!(
                f.ari_serial_vs_parallel > 0.99,
                "{}: parallel clustering diverged (ARI {})",
                f.name,
                f.ari_serial_vs_parallel
            );
        }
        figs
    });
    report(&s);
}
