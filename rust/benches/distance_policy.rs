//! Distance-policy bench: `exact` (subtract-square) vs `dot`
//! (norm-trick FMA micro-kernel) per (policy × tier × (n, d, k)) —
//! the DESIGN.md §11 perf surface, plus the cross-policy correctness
//! check per cell (identical assignments up to documented tie
//! tolerance; serial-engine cells additionally pin identical iteration
//! counts and SSE relative error < 1e-5 on the paper GMM suites).
//!
//!     cargo bench --bench distance_policy
//!
//! Every timed cell lands in `results/bench.json` (the machine-
//! readable perf trajectory published as a CI artifact) with ns/point
//! and the speedup vs the exact-scalar baseline of the same cell.
//!
//! Knobs (also used by CI bench-smoke):
//!   PARAKM_BENCH_N        rows per case (default 200000)
//!   PARAKM_BENCH_WARMUP / PARAKM_BENCH_REPEATS / PARAKM_BENCH_CAP_SECS

use parakmeans::config::DistancePolicy;
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::kmeans::{init, serial, KmeansConfig};
use parakmeans::linalg::kernel::{self, KernelTier};
use parakmeans::linalg::sqdist_f64;
use parakmeans::util::bench::{
    append_bench_json, bench_json_row, report, run_case, BenchOpts,
};
use parakmeans::util::json::Json;

/// Tiers to sweep: scalar always, plus the *active* tier — so a
/// `PARAKM_KERNEL=scalar`-forced run (CI) genuinely sweeps only the
/// reference tier instead of re-timing the detected SIMD tier.
fn tiers() -> Vec<KernelTier> {
    let mut t = vec![KernelTier::Scalar];
    if kernel::active_tier() != KernelTier::Scalar {
        t.push(kernel::active_tier());
    }
    t
}

fn run_exact(rows: &[f32], d: usize, mu: &[f32], k: usize, tier: KernelTier) -> Vec<i32> {
    let n = rows.len() / d;
    let mut assign = vec![0i32; n];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut sse = 0.0f64;
    kernel::assign_accumulate(rows, d, mu, k, &mut assign, &mut sums, &mut counts, &mut sse, tier);
    assign
}

#[allow(clippy::too_many_arguments)]
fn run_dot(
    rows: &[f32],
    d: usize,
    mu: &[f32],
    k: usize,
    xn: &[f32],
    cn: &[f32],
    tier: KernelTier,
) -> Vec<i32> {
    let n = rows.len() / d;
    let mut assign = vec![0i32; n];
    let mut sums = vec![0.0f64; k * d];
    let mut counts = vec![0u64; k];
    let mut sse = 0.0f64;
    kernel::assign_accumulate_dot(
        rows, d, mu, k, xn, cn, &mut assign, &mut sums, &mut counts, &mut sse, tier,
    );
    assign
}

/// Cross-policy check: assignments must agree except where the two
/// candidate distances are within the documented dot rounding
/// tolerance (a razor-thin tie either formulation may break).
fn cross_check(rows: &[f32], d: usize, mu: &[f32], xn: &[f32], a: &[i32], b: &[i32], cell: &str) {
    for i in 0..a.len() {
        if a[i] == b[i] {
            continue;
        }
        let p = &rows[i * d..(i + 1) * d];
        let da = sqdist_f64(p, &mu[a[i] as usize * d..(a[i] as usize + 1) * d]);
        let db = sqdist_f64(p, &mu[b[i] as usize * d..(b[i] as usize + 1) * d]);
        let slack = 1e-4 * (xn[i] as f64 + 1.0);
        assert!(
            (da - db).abs() <= slack,
            "{cell}: point {i} exact→{} dot→{} but distances {da} vs {db} are not a near-tie",
            a[i],
            b[i]
        );
    }
}

fn main() {
    let opts = BenchOpts::from_env();
    let n = opts.n;
    println!("== distance-policy bench (n={n}) ==");
    println!("detected tier: {}  active tier: {}", kernel::detect(), kernel::active_tier());
    let mut json_rows: Vec<Json> = Vec::new();

    // ---- kernel-level sweep: policy × tier × (d, k) --------------------
    for (dim, comps) in [(2usize, 8usize), (3, 4)] {
        let spec = if dim == 2 {
            MixtureSpec::paper_2d(comps)
        } else {
            MixtureSpec::paper_3d(comps)
        };
        let ds = spec.generate(n, 0xD157 + dim as u64);
        let rows = ds.raw();
        let xn = kernel::row_norms_vec(rows, dim);
        for k in [4usize, 8, 16] {
            let mu: Vec<f32> = ds.rows(0, k).to_vec();
            let cn = kernel::row_norms_vec(&mu, dim);

            // correctness per cell, every tier, before any timing
            let a_exact = run_exact(rows, dim, &mu, k, KernelTier::Scalar);
            for tier in tiers() {
                let a_dot = run_dot(rows, dim, &mu, k, &xn, &cn, tier);
                let cell = format!("d={dim} k={k} {tier}");
                cross_check(rows, dim, &mu, &xn, &a_exact, &a_dot, &cell);
            }

            let mut exact_scalar_ns = 0.0f64;
            for tier in tiers() {
                let s = run_case(&format!("exact {tier} d={dim} k={k:<2} n={n}"), &opts, || {
                    run_exact(rows, dim, &mu, k, tier)
                });
                report(&s);
                let ns = s.median() / n as f64 * 1e9;
                if tier == KernelTier::Scalar {
                    exact_scalar_ns = ns;
                }
                json_rows.push(bench_json_row(
                    "distance_policy",
                    "kernel",
                    "exact",
                    &tier.to_string(),
                    n,
                    dim,
                    k,
                    ns,
                    if ns > 0.0 { exact_scalar_ns / ns } else { 0.0 },
                ));

                let s = run_case(&format!("dot   {tier} d={dim} k={k:<2} n={n}"), &opts, || {
                    run_dot(rows, dim, &mu, k, &xn, &cn, tier)
                });
                report(&s);
                let ns = s.median() / n as f64 * 1e9;
                json_rows.push(bench_json_row(
                    "distance_policy",
                    "kernel",
                    "dot",
                    &tier.to_string(),
                    n,
                    dim,
                    k,
                    ns,
                    if ns > 0.0 { exact_scalar_ns / ns } else { 0.0 },
                ));
                println!(
                    "SPEEDUP d={dim} k={k:<2} {tier}  dot/exact-scalar = {:.2}x",
                    if ns > 0.0 { exact_scalar_ns / ns } else { 0.0 }
                );
            }
        }
    }

    // ---- engine-level cells: the acceptance contract on the paper
    // suites — identical assignments and iteration counts, SSE relative
    // error < 1e-5 (serial engine, active tier) ------------------------
    let engine_n = n.min(20_000);
    for (dim, k) in [(2usize, 8usize), (3, 4)] {
        let spec = if dim == 2 { MixtureSpec::paper_2d(k) } else { MixtureSpec::paper_3d(k) };
        let ds = spec.generate(engine_n, 42);
        let cfg = KmeansConfig::new(k).with_seed(5);
        let mu0 = init::initialize(&ds, cfg.k, cfg.init, cfg.seed);
        let exact = serial::run_from(&ds, &cfg, &mu0);
        let dcfg = cfg.clone().with_distance(DistancePolicy::Dot);
        let dot = serial::run_from(&ds, &dcfg, &mu0);
        assert_eq!(dot.assign, exact.assign, "paper {dim}D: dot assignments diverged");
        assert_eq!(dot.iterations, exact.iterations, "paper {dim}D: iteration counts differ");
        let rel = (dot.sse - exact.sse).abs() / exact.sse.max(1.0);
        assert!(rel < 1e-5, "paper {dim}D: sse relative error {rel}");
        println!(
            "CHECK paper {dim}D k={k}: dot == exact over {} iterations (sse rel err {rel:.2e})",
            exact.iterations
        );

        let tier_label = kernel::active_tier().to_string();
        for (policy, pcfg) in [("exact", cfg.clone()), ("dot", dcfg.clone())] {
            let s = run_case(
                &format!("serial {policy} paper{dim}d k={k} n={engine_n}"),
                &opts,
                || serial::run_from(&ds, &pcfg, &mu0),
            );
            report(&s);
            let iters = exact.iterations.max(1);
            json_rows.push(bench_json_row(
                "distance_policy",
                "serial",
                policy,
                &tier_label,
                engine_n,
                dim,
                k,
                s.median() / (engine_n * iters) as f64 * 1e9,
                0.0,
            ));
        }
    }

    // a PARAKM_KERNEL-forced run (the CI scalar pass) re-measures
    // cells the unforced run already wrote — keep the published
    // trajectory free of duplicate conflicting rows by only appending
    // from the auto-dispatch run
    if std::env::var("PARAKM_KERNEL").is_ok() {
        println!("PARAKM_KERNEL forced: skipping results/bench.json append (checks still ran)");
        return;
    }
    let json_path = parakmeans::eval::results_dir().join("bench.json");
    match append_bench_json(&json_path, json_rows) {
        Ok(()) => println!("perf trajectory appended to {}", json_path.display()),
        Err(e) => eprintln!("warning: could not write {}: {e}", json_path.display()),
    }
}
