//! Pruned × parallel sweep: (engine × threads × K × scheduler) on the
//! paper's 3D GMM family — the A3 ablation extended with the chunk
//! scheduler and the pruning counters (DESIGN.md §9).
//!
//!     cargo bench --bench pruned_parallel
//!
//! Knobs (also used by CI bench-smoke):
//!   PARAKM_BENCH_N        dataset rows (default 200000)
//!   PARAKM_BENCH_WARMUP / PARAKM_BENCH_REPEATS / PARAKM_BENCH_CAP_SECS
//!
//! Per cell: wall-clock median, speedup ψ vs the same engine at p = 1,
//! efficiency ε = ψ/p, and the distance-computation skip rate from
//! `KmeansResult::pruning`. Every pruned cell is cross-checked
//! bit-identical against its p = 1 twin (the DESIGN.md §9 contract)
//! before timing — no timing assertions, shape only. Writes
//! `results/tables/pruned.csv` for `eval::report`.

use parakmeans::config::SchedMode;
use parakmeans::data::gmm::workloads;
use parakmeans::data::Dataset;
use parakmeans::eval;
use parakmeans::kmeans::{self, elkan, hamerly, init, parallel, KmeansConfig, KmeansResult};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::util::bench::{report, run_case, BenchOpts};
use parakmeans::util::csv;

#[derive(Clone, Copy, PartialEq)]
enum Eng {
    Threads,
    Elkan,
    Hamerly,
}

impl Eng {
    fn name(self) -> &'static str {
        match self {
            Eng::Threads => "threads",
            Eng::Elkan => "elkan",
            Eng::Hamerly => "hamerly",
        }
    }

    fn run(
        self,
        ds: &Dataset,
        cfg: &KmeansConfig,
        mu0: &[f32],
        p: usize,
        mode: SchedMode,
    ) -> KmeansResult {
        match self {
            Eng::Threads => {
                parallel::run_from_sched(ds, cfg, p, parallel::MergeMode::Leader, mode, mu0)
            }
            Eng::Elkan => elkan::run_from_threads(ds, cfg, p, mode, mu0),
            Eng::Hamerly => hamerly::run_from_threads(ds, cfg, p, mode, mu0),
        }
    }
}

fn main() {
    let opts = BenchOpts::from_env();
    let n = opts.n;
    println!("== pruned × parallel bench (3D, n={n}) ==");

    let ds = eval::paper_dataset(3, n);
    let mut rows: Vec<Vec<String>> = Vec::new();

    for k in [workloads::K_3D, 8] {
        let cfg = KmeansConfig::new(k).with_seed(42);
        let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
        let lloyd = kmeans::serial::run_from(&ds, &cfg, &mu0);
        println!(
            "K={k}: serial Lloyd reference {} iters (converged: {}), sse {:.6e}",
            lloyd.iterations, lloyd.converged, lloyd.sse
        );

        for eng in [Eng::Threads, Eng::Elkan, Eng::Hamerly] {
            let name = eng.name();
            // speedup base: the same engine, one worker, steal mode
            let base_result = eng.run(&ds, &cfg, &mu0, 1, SchedMode::Steal);
            assert_eq!(
                base_result.assign, lloyd.assign,
                "K={k} {name}: diverged from serial Lloyd labels"
            );
            let base = run_case(&format!("{name} K={k} p=1 base"), &opts, || {
                eng.run(&ds, &cfg, &mu0, 1, SchedMode::Steal)
            });
            let t1 = base.median();

            for p in [1usize, 2, 4] {
                for mode in [SchedMode::Static, SchedMode::Steal] {
                    let label = format!("{name:<8} K={k} p={p} {mode}");
                    let (r, s) = if p == 1 && mode == SchedMode::Steal {
                        // this cell IS the base configuration — reuse
                        // its result and timing instead of re-running
                        let s = parakmeans::util::bench::Sample {
                            label: label.clone(),
                            runs: base.runs.clone(),
                        };
                        (base_result.clone(), s)
                    } else {
                        let r = eng.run(&ds, &cfg, &mu0, p, mode);
                        // determinism cross-check (exact, once per
                        // cell): pruned engines are bit-identical to
                        // p = 1 in BOTH modes; the dense engine only
                        // within steal mode (static keeps the
                        // historical per-shard grouping)
                        if eng != Eng::Threads {
                            assert_bit_identical(
                                &r,
                                &base_result,
                                &format!("{name} K={k} p={p} {mode}"),
                            );
                        } else if mode == SchedMode::Steal {
                            assert_bit_identical(
                                &r,
                                &base_result,
                                &format!("{name} K={k} p={p} steal"),
                            );
                        } else {
                            assert_eq!(r.assign, base_result.assign, "{name} K={k} p={p} static");
                        }
                        let s = run_case(&label, &opts, || eng.run(&ds, &cfg, &mu0, p, mode));
                        (r, s)
                    };
                    let skip = r.pruning.as_ref().map(|s| s.skip_rate()).unwrap_or(0.0);
                    report(&s);
                    let secs = s.median();
                    let speedup = t1 / secs.max(1e-12);
                    println!(
                        "         -> speedup {speedup:.2}x  efficiency {:.2}  skip rate {:.1}%",
                        speedup / p as f64,
                        100.0 * skip
                    );
                    rows.push(vec![
                        name.to_string(),
                        k.to_string(),
                        p.to_string(),
                        mode.to_string(),
                        format!("{secs}"),
                        format!("{speedup}"),
                        format!("{}", speedup / p as f64),
                        format!("{skip}"),
                        r.iterations.to_string(),
                    ]);
                }
            }
        }
    }

    let out = eval::results_dir().join("tables/pruned.csv");
    csv::write_rows(
        &out,
        &["engine", "k", "threads", "sched", "secs", "speedup", "efficiency", "skip_rate", "iters"],
        &rows,
    )
    .expect("write pruned.csv");
    println!("wrote {}", out.display());
}
