//! Distributed loopback sweep: (dim × K × workers × scheduler) over
//! the paper's 2D/3D GMM families — the scale axis of DESIGN.md §10,
//! plus the elastic scheduler of §12.
//!
//!     cargo bench --bench dist_scaling
//!
//! Knobs (also used by CI bench-smoke):
//!   PARAKM_BENCH_N        dataset rows (default 200000)
//!   PARAKM_BENCH_WARMUP / PARAKM_BENCH_REPEATS / PARAKM_BENCH_CAP_SECS
//!
//! Per cell: wall-clock median (loopback worker spawn + full run —
//! process-boundary overhead is the thing being measured), speedup ψ vs
//! S = 1, efficiency ε = ψ/S, and per-iteration wire bytes from the
//! leader's NetStats. Every static cell is cross-checked bit-identical
//! against `threads(p = S)` and every elastic cell against
//! `threads(p = S, --sched steal)` before timing (the DESIGN.md §10/§12
//! contracts) — the verdict lands in the CSV's `identical` column so
//! `eval::report` refuses to bless a sweep whose check was skipped.
//! Writes `results/tables/dist.csv` (`sched`: 0 = static, 1 =
//! elastic).
//!
//! A final failure drill runs the elastic scheduler with one of three
//! workers scripted to die mid-iteration, re-checks bit-identity
//! against the fault-free run, and appends the recovery telemetry
//! (re-dispatched chunks, speculative wins, recovery seconds) to
//! `results/bench.json`.

use std::collections::BTreeMap;

use parakmeans::cluster::{LoopbackCluster, SessionFault, WorkerDrill};
use parakmeans::config::SchedMode;
use parakmeans::data::gmm::workloads;
use parakmeans::eval;
use parakmeans::kmeans::dist::{self, DistOpts, DistSched};
use parakmeans::kmeans::{init, parallel, KmeansConfig};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::util::bench::{append_bench_json, report, run_case, BenchOpts};
use parakmeans::util::csv;
use parakmeans::util::json::Json;

fn main() {
    let opts = BenchOpts::from_env();
    let n = opts.n;
    println!("== dist scaling bench (loopback workers, n={n}) ==");

    let net = DistOpts::default();
    let elastic_net = DistOpts { sched: DistSched::Elastic, ..DistOpts::default() };
    let mut rows: Vec<Vec<f64>> = Vec::new();

    for (dim, ks) in [(2usize, vec![workloads::K_2D]), (3usize, vec![workloads::K_3D, 8])] {
        let ds = eval::paper_dataset(dim, n);
        for k in ks {
            let cfg = KmeansConfig::new(k).with_seed(42);
            let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);

            for (sched, sched_code, net) in
                [("static", 0.0, &net), ("elastic", 1.0, &elastic_net)]
            {
                let mut t1 = f64::NAN;
                for s in [1usize, 2, 4] {
                    // identity cross-check once per cell, before
                    // timing: static dist(S) must equal threads(p=S),
                    // elastic dist(S) must equal threads-steal(p=S) —
                    // both bit-for-bit
                    let cluster = spawn(&ds, s, net.sched);
                    let run = dist::run_from(&cluster.addrs, &cfg, net, &mu0)
                        .expect("distributed run");
                    cluster.join().expect("workers exit cleanly");
                    let reference = match net.sched {
                        DistSched::Static => {
                            parallel::run_from(&ds, &cfg, s, parallel::MergeMode::Leader, &mu0)
                        }
                        DistSched::Elastic => parallel::run_from_sched(
                            &ds,
                            &cfg,
                            s,
                            parallel::MergeMode::Leader,
                            SchedMode::Steal,
                            &mu0,
                        ),
                    };
                    assert_bit_identical(
                        &run.result,
                        &reference,
                        &format!("{dim}D K={k} S={s} {sched}"),
                    );
                    let bytes_per_iter = run.net.bytes_per_iter();
                    let iters = run.result.iterations;
                    let sse = run.result.sse;

                    // timed runs: spawn + run, the full process-
                    // boundary cost a real deployment pays per job
                    let label = format!("{dim}D K={k} S={s} {sched}");
                    let sample = run_case(&label, &opts, || {
                        let cluster = spawn(&ds, s, net.sched);
                        let run = dist::run_from(&cluster.addrs, &cfg, net, &mu0)
                            .expect("distributed run");
                        cluster.join().expect("workers exit cleanly");
                        run
                    });
                    report(&sample);
                    let secs = sample.median();
                    if s == 1 {
                        t1 = secs;
                    }
                    let speedup = t1 / secs.max(1e-12);
                    println!(
                        "         -> speedup {speedup:.2}x  efficiency {:.2}  wire {:.1} KiB/iter",
                        speedup / s as f64,
                        bytes_per_iter / 1024.0
                    );
                    rows.push(vec![
                        dim as f64,
                        k as f64,
                        s as f64,
                        sched_code,
                        secs,
                        speedup,
                        speedup / s as f64,
                        bytes_per_iter,
                        iters as f64,
                        sse,
                        1.0, // identity check passed (assert above)
                    ]);
                }
            }
        }
    }

    let out = eval::results_dir().join("tables/dist.csv");
    csv::write_table(
        &out,
        &[
            "dim", "k", "workers", "sched", "secs", "speedup", "efficiency", "bytes_per_iter",
            "iters", "sse", "identical",
        ],
        &rows,
    )
    .expect("write dist.csv");
    println!("wrote {}", out.display());

    failure_drill(n);
}

fn spawn(ds: &parakmeans::data::Dataset, s: usize, sched: DistSched) -> LoopbackCluster {
    match sched {
        // static: contiguous shards, one per worker
        DistSched::Static => {
            LoopbackCluster::spawn_dataset(ds, s, 65_536).expect("spawn loopback cluster")
        }
        // elastic: every worker holds the full dataset (replicated
        // inputs — the §12 deployment model)
        DistSched::Elastic => {
            LoopbackCluster::spawn_replicated(ds, s, 65_536).expect("spawn loopback cluster")
        }
    }
}

/// Elastic recovery drill: 3 replicated workers, one dies after its
/// first chunk. The run must complete bit-identical to the fault-free
/// elastic run; the recovery telemetry lands in `results/bench.json`.
fn failure_drill(n: usize) {
    println!("== elastic failure drill (3 workers, one killed mid-iteration) ==");
    let ds = eval::paper_dataset(2, n);
    let k = workloads::K_2D;
    let cfg = KmeansConfig::new(k).with_seed(42);
    let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
    let net = DistOpts { sched: DistSched::Elastic, ..DistOpts::default() };

    let clean_cluster = LoopbackCluster::spawn_replicated(&ds, 3, 65_536).expect("spawn");
    let clean = dist::run_from(&clean_cluster.addrs, &cfg, &net, &mu0).expect("clean run");
    clean_cluster.join().expect("workers exit cleanly");

    let drills = [
        WorkerDrill {
            fault: SessionFault { die_after_chunks: Some(1), ..Default::default() },
            sessions: 1,
        },
        WorkerDrill::default(),
        WorkerDrill::default(),
    ];
    let t0 = std::time::Instant::now();
    let cluster = LoopbackCluster::spawn_replicated_faulty(&ds, 65_536, &drills).expect("spawn");
    let faulty = dist::run_from(&cluster.addrs, &cfg, &net, &mu0).expect("drilled run");
    let secs = t0.elapsed().as_secs_f64();
    cluster.join().expect("workers exit cleanly");

    assert_bit_identical(&faulty.result, &clean.result, "drill: faulty vs fault-free");
    let net_stats = &faulty.net;
    println!(
        "DRILL  failures={} rejoins={} redispatched={} speculative={} (wins {}) \
         recovery={:.3}s total={secs:.3}s  [bit-identical to fault-free]",
        net_stats.worker_failures,
        net_stats.worker_rejoins,
        net_stats.redispatched_chunks,
        net_stats.speculative_chunks,
        net_stats.speculative_wins,
        net_stats.recovery_secs
    );

    let mut row = BTreeMap::new();
    row.insert("bench".to_string(), Json::Str("dist_scaling".to_string()));
    row.insert("engine".to_string(), Json::Str("dist-elastic-drill".to_string()));
    row.insert("n".to_string(), Json::Num(ds.len() as f64));
    row.insert("d".to_string(), Json::Num(ds.dim() as f64));
    row.insert("k".to_string(), Json::Num(k as f64));
    row.insert("workers".to_string(), Json::Num(3.0));
    row.insert("secs".to_string(), Json::Num(secs));
    row.insert("iters".to_string(), Json::Num(faulty.result.iterations as f64));
    row.insert(
        "worker_failures".to_string(),
        Json::Num(net_stats.worker_failures as f64),
    );
    row.insert("worker_rejoins".to_string(), Json::Num(net_stats.worker_rejoins as f64));
    row.insert(
        "redispatched_chunks".to_string(),
        Json::Num(net_stats.redispatched_chunks as f64),
    );
    row.insert(
        "speculative_chunks".to_string(),
        Json::Num(net_stats.speculative_chunks as f64),
    );
    row.insert(
        "speculative_wins".to_string(),
        Json::Num(net_stats.speculative_wins as f64),
    );
    row.insert("recovery_secs".to_string(), Json::Num(net_stats.recovery_secs));
    row.insert("bit_identical_to_fault_free".to_string(), Json::Bool(true));
    let out = eval::results_dir().join("bench.json");
    append_bench_json(&out, vec![Json::Obj(row)]).expect("append bench.json");
    println!("wrote {}", out.display());
}
