//! Distributed loopback sweep: (dim × K × workers) over the paper's
//! 2D/3D GMM families — the scale axis of DESIGN.md §10.
//!
//!     cargo bench --bench dist_scaling
//!
//! Knobs (also used by CI bench-smoke):
//!   PARAKM_BENCH_N        dataset rows (default 200000)
//!   PARAKM_BENCH_WARMUP / PARAKM_BENCH_REPEATS / PARAKM_BENCH_CAP_SECS
//!
//! Per cell: wall-clock median (loopback worker spawn + full run —
//! process-boundary overhead is the thing being measured), speedup ψ vs
//! S = 1, efficiency ε = ψ/S, and per-iteration wire bytes from the
//! leader's NetStats. Every cell is cross-checked bit-identical against
//! `threads(p = S)` before timing (the DESIGN.md §10 contract) — the
//! verdict lands in the CSV's `identical` column so `eval::report`
//! refuses to bless a sweep whose check was skipped. Writes
//! `results/tables/dist.csv`.

use parakmeans::cluster::LoopbackCluster;
use parakmeans::data::gmm::workloads;
use parakmeans::eval;
use parakmeans::kmeans::dist::{self, DistOpts};
use parakmeans::kmeans::{init, parallel, KmeansConfig};
use parakmeans::testutil::assert_bit_identical;
use parakmeans::util::bench::{report, run_case, BenchOpts};
use parakmeans::util::csv;

fn main() {
    let opts = BenchOpts::from_env();
    let n = opts.n;
    println!("== dist scaling bench (loopback workers, n={n}) ==");

    let net = DistOpts::default();
    let mut rows: Vec<Vec<f64>> = Vec::new();

    for (dim, ks) in [(2usize, vec![workloads::K_2D]), (3usize, vec![workloads::K_3D, 8])] {
        let ds = eval::paper_dataset(dim, n);
        for k in ks {
            let cfg = KmeansConfig::new(k).with_seed(42);
            let mu0 = init::initialize(&ds, k, cfg.init, cfg.seed);
            let mut t1 = f64::NAN;

            for s in [1usize, 2, 4] {
                // identity cross-check once per cell, before timing:
                // dist(S) must equal threads(p=S) bit-for-bit
                let cluster = LoopbackCluster::spawn_dataset(&ds, s, 65_536)
                    .expect("spawn loopback cluster");
                let run = dist::run_from(&cluster.addrs, &cfg, &net, &mu0)
                    .expect("distributed run");
                cluster.join().expect("workers exit cleanly");
                let threads = parallel::run_from(&ds, &cfg, s, parallel::MergeMode::Leader, &mu0);
                assert_bit_identical(&run.result, &threads, &format!("{dim}D K={k} S={s}"));
                let bytes_per_iter = run.net.bytes_per_iter();
                let iters = run.result.iterations;
                let sse = run.result.sse;

                // timed runs: spawn + run, the full process-boundary
                // cost a real deployment pays per job
                let label = format!("{dim}D K={k} S={s}");
                let sample = run_case(&label, &opts, || {
                    let cluster = LoopbackCluster::spawn_dataset(&ds, s, 65_536)
                        .expect("spawn loopback cluster");
                    let run = dist::run_from(&cluster.addrs, &cfg, &net, &mu0)
                        .expect("distributed run");
                    cluster.join().expect("workers exit cleanly");
                    run
                });
                report(&sample);
                let secs = sample.median();
                if s == 1 {
                    t1 = secs;
                }
                let speedup = t1 / secs.max(1e-12);
                println!(
                    "         -> speedup {speedup:.2}x  efficiency {:.2}  wire {:.1} KiB/iter",
                    speedup / s as f64,
                    bytes_per_iter / 1024.0
                );
                rows.push(vec![
                    dim as f64,
                    k as f64,
                    s as f64,
                    secs,
                    speedup,
                    speedup / s as f64,
                    bytes_per_iter,
                    iters as f64,
                    sse,
                    1.0, // identity check passed (assert above)
                ]);
            }
        }
    }

    let out = eval::results_dir().join("tables/dist.csv");
    csv::write_table(
        &out,
        &[
            "dim", "k", "workers", "secs", "speedup", "efficiency", "bytes_per_iter", "iters",
            "sse", "identical",
        ],
        &rows,
    )
    .expect("write dist.csv");
    println!("wrote {}", out.display());
}
