//! Bench T5 — regenerates paper Table 5: 3D dataset size vs
//! offload-engine time (K = 4).
//!
//!     PARAKM_SCALE=full cargo bench --bench table5_offload_3d

use parakmeans::eval::{tables, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts::from_env();
    println!("== TABLE 5 bench (scale {scale:?}) ==");
    let sample = run_case("table5(all cells)", &opts, || {
        tables::table5(scale).expect("table5")
    });
    report(&sample);
}
