//! Bench T3 — regenerates paper Table 3: 3D dataset family,
//! shared-memory engine time vs threads p ∈ {2, 4, 8, 16} (K = 4).
//!
//!     PARAKM_SCALE=full cargo bench --bench table3_shared_3d

use parakmeans::eval::{tables, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts::from_env();
    println!("== TABLE 3 bench (scale {scale:?}) ==");
    let sample = run_case("table3(all cells)", &opts, || {
        tables::table3(scale).expect("table3")
    });
    report(&sample);
}
