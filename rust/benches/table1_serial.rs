//! Bench T1 — regenerates paper Table 1: serial convergence time vs
//! K ∈ {4, 8, 11} on the largest 2D (500k) and 3D (1M) datasets.
//!
//!     PARAKM_SCALE=full cargo bench --bench table1_serial
//!
//! Measurement: the eval runner performs the full convergence run; the
//! house harness wraps it with warmup + repeats (BenchOpts).

use parakmeans::eval::{tables, Scale};
use parakmeans::util::bench::{report, run_case, BenchOpts};

fn main() {
    let scale = Scale::from_env();
    let opts = BenchOpts::from_env();
    println!("== TABLE 1 bench (scale {scale:?}) ==");
    let sample = run_case("table1(all cells)", &opts, || {
        tables::table1(scale).expect("table1")
    });
    report(&sample);
}
