//! Microbenchmarks of the L3 hot path (EXPERIMENTS.md §Perf).
//!
//! Isolates the pieces the profile showed matter:
//!  - `assign_accumulate` (the per-shard inner loop) at d = 2/3,
//!    K = 4/8/11 — points/sec, on the active kernel tier;
//!  - PartialStats merge (the leader's per-worker fold);
//!  - one `stats_partial` call per chunk size — executor call overhead
//!    + per-point throughput (AOT artifacts when built, the native
//!    backend otherwise);
//!  - end-to-end shared engine on one workload.
//!
//!     cargo bench --bench hotpath_micro
//!
//! CI bench-smoke runs this with PARAKM_BENCH_WARMUP=0
//! PARAKM_BENCH_REPEATS=1 (one iteration, no timing assertions).

use parakmeans::config::RunConfig;
use parakmeans::coordinator::shared::{run_with, MergePolicy};
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::kmeans::step::{assign_accumulate, PartialStats};
use parakmeans::linalg::kernel;
use parakmeans::rng::Pcg64;
use parakmeans::runtime::manifest::ExecKind;
use parakmeans::runtime::Runtime;
use parakmeans::util::bench::{
    append_bench_json, bench_json_row, report, run_case, BenchOpts,
};

fn main() {
    let opts = BenchOpts::from_env();
    println!("== hot-path microbench ==");
    println!("kernel tier: {} (detected: {})", kernel::active_tier(), kernel::detect());

    // ---- assign_accumulate throughput ---------------------------------
    // each case also lands in results/bench.json — the machine-readable
    // perf trajectory CI publishes so future PRs can diff ns/point
    let mut json_rows = Vec::new();
    let tier_label = kernel::active_tier().to_string();
    let n = opts.n;
    for (d, ks) in [(2usize, [4usize, 8, 11]), (3, [4, 8, 11])] {
        let mut rng = Pcg64::new(1, 0);
        let rows: Vec<f32> = (0..n * d).map(|_| rng.next_f32() * 20.0).collect();
        for k in ks {
            let mu: Vec<f32> = (0..k * d).map(|_| rng.next_f32() * 20.0).collect();
            let mut assign = vec![0i32; n];
            let mut stats = PartialStats::zeros(k, d);
            let s = run_case(&format!("assign_accumulate d={d} k={k} n={n}"), &opts, || {
                assign_accumulate(&rows, d, &mu, k, &mut assign, &mut stats).unwrap();
            });
            report(&s);
            println!(
                "         -> {:.1} Mpoints/s",
                n as f64 / s.median() / 1e6
            );
            json_rows.push(bench_json_row(
                "hotpath_micro",
                "kernel",
                "exact",
                &tier_label,
                n,
                d,
                k,
                s.median() / n as f64 * 1e9,
                0.0,
            ));
        }
    }
    // ---- tracing-off overhead guard (DESIGN.md §15) --------------------
    // spans must cost one relaxed load when no trace is installed; this
    // row puts a number on it in the trajectory so a regression that
    // sneaks a syscall or lock into the disabled path is visible in the
    // bench.json diff. Measured as ns per span over a tight loop.
    {
        assert!(!parakmeans::util::trace::enabled());
        const SPANS: usize = 1_000_000;
        let s = run_case(&format!("trace disabled span x{SPANS}"), &opts, || {
            for _ in 0..SPANS {
                let _s = parakmeans::util::trace::span(parakmeans::util::trace::Phase::Assign);
            }
        });
        report(&s);
        let ns_per_span = s.median() / SPANS as f64 * 1e9;
        println!("         -> {ns_per_span:.2} ns/span with tracing off");
        json_rows.push(bench_json_row(
            "hotpath_micro",
            "trace-off-span",
            "exact",
            &tier_label,
            SPANS,
            0,
            0,
            ns_per_span,
            0.0,
        ));
    }

    // ---- chaos-off overhead guard (DESIGN.md §16) ----------------------
    // like the trace guard: with no chaos plan installed a site poll
    // must cost one relaxed load. This row pins the disabled fast path
    // in the trajectory so a lock or allocation sneaking into it shows
    // up in the bench.json diff.
    {
        assert!(!parakmeans::util::chaos::enabled());
        const HITS: usize = 1_000_000;
        let s = run_case(&format!("chaos disabled site x{HITS}"), &opts, || {
            for _ in 0..HITS {
                let f = parakmeans::util::chaos::hit(parakmeans::util::chaos::Site::WireRead);
                assert!(f.is_none());
            }
        });
        report(&s);
        let ns_per_hit = s.median() / HITS as f64 * 1e9;
        println!("         -> {ns_per_hit:.2} ns/site with chaos off");
        json_rows.push(bench_json_row(
            "hotpath_micro",
            "chaos-off-site",
            "exact",
            &tier_label,
            HITS,
            0,
            0,
            ns_per_hit,
            0.0,
        ));
    }

    let json_path = parakmeans::eval::results_dir().join("bench.json");
    if let Err(e) = append_bench_json(&json_path, json_rows) {
        eprintln!("warning: could not write {}: {e}", json_path.display());
    } else {
        println!("perf trajectory appended to {}", json_path.display());
    }

    // ---- merge cost (leader fold) --------------------------------------
    for (k, d) in [(4usize, 3usize), (8, 2), (11, 2)] {
        let mut a = PartialStats::zeros(k, d);
        let b = PartialStats::zeros(k, d);
        let s = run_case(&format!("stats merge k={k} d={d} x1000"), &opts, || {
            for _ in 0..1000 {
                a.merge(&b);
            }
        });
        report(&s);
    }

    // ---- executor call overhead + throughput per chunk ------------------
    let dir = std::path::Path::new("artifacts");
    let mut rt = Runtime::new_or_native(dir).expect("runtime");
    println!(
        "executor backend: {}",
        if rt.is_native_fallback() { "native (synthetic manifest)" } else { "AOT artifacts" }
    );
    for chunk in [4096usize, 65536] {
        let Ok(spec) = rt.find(ExecKind::StatsPartial, 3, 4, chunk) else {
            continue;
        };
        let mut rng = Pcg64::new(2, 0);
        let x: Vec<f32> = (0..chunk * 3).map(|_| rng.next_f32() * 20.0).collect();
        let mu: Vec<f32> = (0..12).map(|_| rng.next_f32() * 20.0).collect();
        let xb = rt.upload_f32(&x, &[chunk, 3]).unwrap();
        let nvb = rt.upload_i32(&[chunk as i32], &[1]).unwrap();
        rt.prepare(&spec).unwrap();
        let mub = rt.upload_f32(&mu, &[4, 3]).unwrap();
        let s = run_case(&format!("exec stats_partial d=3 k=4 chunk={chunk}"), &opts, || {
            rt.execute_buffers(&spec, &[&xb, &mub, &nvb]).unwrap()
        });
        report(&s);
        println!(
            "         -> {:.1} Mpoints/s through the executor",
            chunk as f64 / s.median() / 1e6
        );
    }

    // ---- end-to-end shared engine, one workload -------------------------
    let e2e_n = n.min(100_000);
    let ds = MixtureSpec::paper_3d(4).generate(e2e_n, 9);
    let cfg = RunConfig { k: 4, seed: 42, ..Default::default() };
    let s = run_case(&format!("shared engine e2e n={e2e_n} p=4"), &opts, || {
        run_with(&mut rt, &ds, &cfg, 4, MergePolicy::Leader).unwrap()
    });
    report(&s);
}
