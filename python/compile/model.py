"""L2 — the Lloyd iteration as jax programs, built on the L1 kernel.

Three programs are AOT-lowered per (d, K, chunk) variant — see DESIGN.md
§2 for why these three and how the rust engines use them:

- ``assign_partial``: one chunk -> (assignments, per-cluster partial
  sums/counts, chunk SSE). The shared-memory engine's workers call this
  on their shards; the leader merges partials (the paper's OpenMP
  "local means -> critical-section merge" step).
- ``fused_step``: ``assign_partial`` plus running-accumulator add. The
  offload engine streams chunks through this, keeping the accumulators
  device-side (the paper's OpenACC model: reductions happen on device).
- ``finalize``: merged (sums, counts, mu_old) -> (mu_new, shift error E).
  E is the paper's convergence criterion Σ_k ||μ^{t+1}_k − μ^t_k||².

Python never runs at request time: these exist only to be lowered by
``aot.py``. K is padded to a lane-friendly multiple inside the programs;
the artifact boundary (what rust sees) always uses the *real* K.
"""

from __future__ import annotations

import jax.numpy as jnp

from .kernels import lloyd as L


def make_assign_partial(d: int, k: int, chunk: int, tile_n: int):
    """Build ``assign_partial`` for one (d, K, chunk) variant.

    Signature: (x[chunk,d] f32, mu[k,d] f32, n_valid[1] i32)
            -> (assign[chunk] i32, sums[k,d] f32, counts[k] f32, sse[1] f32)
    """
    kp = L.pad_k(k)

    def assign_partial(x, mu, n_valid):
        mu_p = L.pad_centroids(mu, kp)
        a, sums_p, counts_p, sse = L.lloyd_chunk(x, mu_p, n_valid, tile_n=tile_n)
        return a, sums_p[:k], counts_p[:k], sse

    return assign_partial


def make_stats_partial(d: int, k: int, chunk: int, tile_n: int):
    """``assign_partial`` without the assignment output.

    Signature: (x, mu, n_valid) -> (sums[k,d], counts[k], sse[1]).

    The engines drive this in the iteration loop: the per-call result
    is ~(k·d + k + 1) floats instead of a chunk-sized assignment array,
    so the PJRT tuple fetch is bytes, not megabytes (§Perf L2-1). XLA
    dead-code-eliminates the argmin write in the lowered module; the
    final assignments come from one post-convergence pass over
    :func:`make_assign_only`.
    """
    assign_partial = make_assign_partial(d, k, chunk, tile_n)

    def stats_partial(x, mu, n_valid):
        _, sums, counts, sse = assign_partial(x, mu, n_valid)
        return sums, counts, sse

    return stats_partial


def make_assign_only(d: int, k: int, chunk: int, tile_n: int):
    """Assignment-only program, run once after convergence.

    Signature: (x, mu, n_valid) -> (assign[chunk] i32,)
    """
    assign_partial = make_assign_partial(d, k, chunk, tile_n)

    def assign_only(x, mu, n_valid):
        a, _, _, _ = assign_partial(x, mu, n_valid)
        return (a,)

    return assign_only


def make_fused_stats(d: int, k: int, chunk: int, tile_n: int):
    """``fused_step`` without the assignment output (offload engine's
    device-side running reduction — the OpenACC `reduction` analog).

    Signature: (x, mu, acc_sums, acc_counts, acc_sse, n_valid)
            -> (new_sums, new_counts, new_sse)
    """
    stats_partial = make_stats_partial(d, k, chunk, tile_n)

    def fused_stats(x, mu, acc_sums, acc_counts, acc_sse, n_valid):
        sums, counts, sse = stats_partial(x, mu, n_valid)
        return acc_sums + sums, acc_counts + counts, acc_sse + sse

    return fused_stats


def make_fused_step(d: int, k: int, chunk: int, tile_n: int):
    """Build ``fused_step`` for one (d, K, chunk) variant.

    Signature: (x, mu, acc_sums[k,d], acc_counts[k], acc_sse[1], n_valid)
            -> (assign, new_sums, new_counts, new_sse)

    The accumulators are passed in and returned so the offload engine can
    keep them resident across the chunks of one Lloyd iteration.
    """
    assign_partial = make_assign_partial(d, k, chunk, tile_n)

    def fused_step(x, mu, acc_sums, acc_counts, acc_sse, n_valid):
        a, sums, counts, sse = assign_partial(x, mu, n_valid)
        return a, acc_sums + sums, acc_counts + counts, acc_sse + sse

    return fused_step


def make_finalize(d: int, k: int):
    """Build ``finalize`` for one (d, K) variant.

    Signature: (sums[k,d] f32, counts[k] f32, mu_old[k,d] f32)
            -> (mu_new[k,d] f32, shift[1] f32)

    Empty clusters keep their previous centroid (deterministic, matches
    the serial rust baseline bit-for-bit in intent; the paper's code
    assumes clusters never empty).
    """

    def finalize(sums, counts, mu_old):
        safe = jnp.maximum(counts, 1.0)[:, None]
        mu_new = jnp.where(counts[:, None] > 0.0, sums / safe, mu_old)
        diff = mu_new - mu_old
        shift = jnp.sum(diff * diff)[None]
        return mu_new, shift

    return finalize
