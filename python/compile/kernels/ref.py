"""Pure-jnp reference oracle for the Pallas kernels.

Every Pallas kernel in this package has a reference implementation here,
written with nothing but `jax.numpy` ops in the most obvious way possible.
pytest (``python/tests/``) sweeps shapes/dtypes with hypothesis and asserts
the kernels match these oracles; the kernels are only trusted through that
equivalence.

Conventions (shared with the kernels and the rust runtime):

- ``x``       : ``[n, d]`` float32 chunk of data points (possibly padded).
- ``mu``      : ``[k, d]`` float32 current centroids.
- ``n_valid`` : int32 scalar — number of *real* rows in ``x``; rows at
  index >= n_valid are padding and must not contribute to any statistic.
- assignments are int32 in ``[0, k)``; padded rows get assignment ``-1``.
"""

from __future__ import annotations

import jax.numpy as jnp


def sq_distances(x: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """Full [n, k] matrix of squared L2 distances ||x_i - mu_j||^2.

    Computed the naive way (explicit difference) so it cannot share a bug
    with the kernel's ``||x||^2 - 2 x.mu + ||mu||^2`` expansion.
    """
    diff = x[:, None, :] - mu[None, :, :]  # [n, k, d]
    return jnp.sum(diff * diff, axis=-1)


def assign(x: jnp.ndarray, mu: jnp.ndarray, n_valid: jnp.ndarray) -> jnp.ndarray:
    """Nearest-centroid assignment; padded rows -> -1."""
    d2 = sq_distances(x, mu)
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)
    row = jnp.arange(x.shape[0], dtype=jnp.int32)
    return jnp.where(row < n_valid, a, jnp.int32(-1))


def partial_stats(x, mu, n_valid):
    """Reference for the ``assign_partial`` executable.

    Returns (assign[n] i32, sums[k,d] f32, counts[k] f32, sse[] f32):
    per-cluster sums/counts over the valid rows plus the summed squared
    distance of each valid point to its chosen centroid.
    """
    k = mu.shape[0]
    a = assign(x, mu, n_valid)
    valid = a >= 0
    onehot = (a[:, None] == jnp.arange(k, dtype=jnp.int32)[None, :]).astype(x.dtype)
    sums = onehot.T @ x  # [k, d]
    counts = jnp.sum(onehot, axis=0)  # [k]
    d2 = sq_distances(x, mu)
    best = jnp.min(d2, axis=1)
    sse = jnp.sum(jnp.where(valid, best, 0.0))
    return a, sums, counts, sse


def fused_step(x, mu, acc_sums, acc_counts, acc_sse, n_valid):
    """Reference for the ``fused_step`` executable: running accumulators.

    The offload engine streams chunks through this, keeping the
    accumulators device-resident between calls within one Lloyd iteration.
    """
    a, sums, counts, sse = partial_stats(x, mu, n_valid)
    return a, acc_sums + sums, acc_counts + counts, acc_sse + sse


def finalize(sums, counts, mu_old):
    """Reference for the ``finalize`` executable.

    New centroids = sums / counts, with empty clusters keeping their old
    centroid (the paper's C implementation divides by the count and
    relies on no cluster emptying; we make the empty case explicit and
    deterministic). Also returns the paper's convergence error
    E = sum_k ||mu_new_k - mu_old_k||^2.
    """
    safe = jnp.maximum(counts, 1.0)[:, None]
    mu_new = jnp.where(counts[:, None] > 0, sums / safe, mu_old)
    diff = mu_new - mu_old
    shift = jnp.sum(diff * diff)
    return mu_new, shift
