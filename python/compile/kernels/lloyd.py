"""L1 — the Lloyd-iteration hot-spot as a Pallas kernel.

One fused kernel does, per tile of the chunk dimension:

1. squared L2 distances to every centroid via the MXU-friendly expansion
   ``||x||^2 - 2 x.mu^T + ||mu||^2`` (the cross term is a
   ``[tile_n, d] x [d, kp]`` matmul that maps onto the systolic array);
2. argmin over centroids -> assignment;
3. per-cluster partial sums / counts via a one-hot matmul
   (``onehot^T @ x`` — the TPU-native replacement for the paper's
   OpenACC ``atomic`` adds; TPUs have no atomics) and the tile's SSE;
4. accumulation of 3. into chunk-level output refs across grid steps
   (constant output index_map -> the output block is revisited every
   step; initialized at step 0).

Hardware adaptation notes (DESIGN.md §3): the BlockSpec grid expresses
the HBM->VMEM streaming schedule the paper expressed with OpenACC gangs:
x tiles stream through VMEM while the (tiny) centroid block stays
resident. K is padded to ``kp`` (lane-friendly multiple) by the caller
with +large sentinel centroids so argmin never selects padding.

``interpret=True`` is mandatory on this image: CPU PJRT cannot execute
Mosaic custom-calls. The kernel is structured for TPU anyway; interpret
mode traces the same program into portable HLO.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Sentinel coordinate for padded centroid rows. Chosen so that
# ||sentinel||^2 (~1e34 * d) stays finite in f32 while dwarfing any real
# distance; padded rows therefore never win the argmin.
PAD_SENTINEL = 1.0e17


def _lloyd_tile_kernel(
    nvalid_ref,  # [1]   i32, whole-array block (chunk-global valid count)
    x_ref,       # [tile_n, d] f32 — this grid step's tile of points
    mu_ref,      # [kp, d]     f32 — padded centroids, resident every step
    assign_ref,  # [tile_n]    i32 out — this tile's assignments
    sums_ref,    # [kp, d]     f32 out — chunk-level accumulator (revisited)
    counts_ref,  # [kp]        f32 out — chunk-level accumulator (revisited)
    sse_ref,     # [1]         f32 out — chunk-level accumulator (revisited)
    *,
    tile_n: int,
):
    step = pl.program_id(0)

    x = x_ref[...]                                   # [tn, d]
    mu = mu_ref[...]                                 # [kp, d]
    kp = mu.shape[0]

    # -- 1. distances via the matmul expansion (MXU path) ----------------
    xsq = jnp.sum(x * x, axis=1, keepdims=True)      # [tn, 1]
    musq = jnp.sum(mu * mu, axis=1)[None, :]         # [1, kp]
    cross = jax.lax.dot_general(
        x, mu,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # [tn, kp] = x @ mu^T
    d2 = xsq - 2.0 * cross + musq                    # [tn, kp]

    # -- 2. assignment ----------------------------------------------------
    a = jnp.argmin(d2, axis=1).astype(jnp.int32)     # [tn]
    rows = step * tile_n + jax.lax.iota(jnp.int32, tile_n)
    valid = rows < nvalid_ref[0]                     # [tn] bool
    assign_ref[...] = jnp.where(valid, a, jnp.int32(-1))

    # -- 3. tile-local statistics (one-hot matmul, no atomics) ------------
    kiota = jax.lax.iota(jnp.int32, kp)              # [kp]
    onehot = jnp.where(
        valid[:, None], (a[:, None] == kiota[None, :]).astype(x.dtype), 0.0
    )                                                # [tn, kp]
    tile_sums = jax.lax.dot_general(
        onehot, x,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                                # [kp, d] = onehot^T @ x
    tile_counts = jnp.sum(onehot, axis=0)            # [kp]
    best = jnp.min(d2, axis=1)                       # [tn]
    # Distances are mathematically >= 0 but the expansion can go slightly
    # negative in f32; clamp so SSE stays a valid sum of squares.
    best = jnp.maximum(best, 0.0)
    tile_sse = jnp.sum(jnp.where(valid, best, 0.0))[None]  # [1]

    # -- 4. cross-step accumulation into the revisited output block -------
    @pl.when(step == 0)
    def _init():
        sums_ref[...] = jnp.zeros_like(sums_ref)
        counts_ref[...] = jnp.zeros_like(counts_ref)
        sse_ref[...] = jnp.zeros_like(sse_ref)

    sums_ref[...] += tile_sums
    counts_ref[...] += tile_counts
    sse_ref[...] += tile_sse


@functools.partial(jax.jit, static_argnames=("tile_n",))
def lloyd_chunk(x, mu_padded, n_valid, *, tile_n: int = 2048):
    """Run the fused assign+accumulate kernel over one chunk.

    Args:
      x:         [chunk, d] f32; chunk must be a multiple of ``tile_n``.
      mu_padded: [kp, d] f32, padded with ``PAD_SENTINEL`` rows beyond the
                 real K (see :func:`pad_centroids`).
      n_valid:   [] or [1] i32 — rows of ``x`` beyond this are padding.
      tile_n:    grid tile along the chunk dimension.

    Returns:
      (assign[chunk] i32, sums[kp, d] f32, counts[kp] f32, sse[1] f32).
    """
    chunk, d = x.shape
    kp = mu_padded.shape[0]
    if chunk % tile_n != 0:
        raise ValueError(f"chunk {chunk} not a multiple of tile_n {tile_n}")
    grid = (chunk // tile_n,)
    nv = jnp.reshape(n_valid.astype(jnp.int32), (1,))

    kernel = functools.partial(_lloyd_tile_kernel, tile_n=tile_n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),            # n_valid
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),   # x: streamed
            pl.BlockSpec((kp, d), lambda i: (0, 0)),       # mu: resident
        ],
        out_specs=[
            pl.BlockSpec((tile_n,), lambda i: (i,)),       # assign
            pl.BlockSpec((kp, d), lambda i: (0, 0)),       # sums (revisited)
            pl.BlockSpec((kp,), lambda i: (0,)),           # counts (revisited)
            pl.BlockSpec((1,), lambda i: (0,)),            # sse (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((chunk,), jnp.int32),
            jax.ShapeDtypeStruct((kp, d), jnp.float32),
            jax.ShapeDtypeStruct((kp,), jnp.float32),
            jax.ShapeDtypeStruct((1,), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(nv, x, mu_padded)


def pad_k(k: int) -> int:
    """Lane-friendly padded cluster count (next multiple of 8, min 8)."""
    return max(8, -(-k // 8) * 8)


def pad_centroids(mu: jnp.ndarray, kp: int) -> jnp.ndarray:
    """Pad [k, d] centroids to [kp, d] with sentinel rows."""
    k, d = mu.shape
    if kp < k:
        raise ValueError(f"kp {kp} < k {k}")
    pad = jnp.full((kp - k, d), PAD_SENTINEL, dtype=mu.dtype)
    return jnp.concatenate([mu, pad], axis=0)
