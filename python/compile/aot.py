"""AOT pipeline: lower the L2 programs to HLO text + manifest.

``python -m compile.aot --out ../artifacts`` emits, for every variant in
VARIANTS, three artifacts (assign_partial / fused_step / finalize) as HLO
*text* plus a single ``manifest.json`` that the rust runtime parses to
know each executable's name, file, and input/output signature.

HLO text — NOT ``lowered.compiler_ir("hlo").as_hlo_text()`` on new jax,
and NOT serialized HloModuleProto — is the interchange format: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (what
the published ``xla`` rust crate binds) rejects; the text parser reassigns
ids and round-trips cleanly. We lower to stablehlo and convert via
xla_client, exactly like /opt/xla-example/gen_hlo.py.

This runs at build time only (``make artifacts``); the rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Streaming chunk sizes (points per executable call) and kernel tile.
# Multiple sizes let the rust planner greedily fit shards with bounded
# padding waste (plan.rs): big chunks amortize launch overhead on large
# shards, the small chunk caps padding on shard tails.
CHUNKS = [4096, 65536]
DEFAULT_CHUNK = 65536
# 32768 measured ~17% faster than 8192 through XLA CPU (§Perf L1-1);
# on TPU this is the VMEM-resident x-tile: 32768×3×4B = 384 KiB ≪ VMEM.
DEFAULT_TILE = 32768

# (d, k) variants covering every paper experiment:
#   2D: K=8 for Tables 2/4, K=11 for Figures 5/6, K=4 for Table 1.
#   3D: K=4 for Tables 3/5 + Figures 1-4, K=8/11 for Table 1.
VARIANTS = [
    (2, 4), (2, 8), (2, 11),
    (3, 4), (3, 8), (3, 11),
]

# Chunk-size ablation (DESIGN.md A1) — only for the headline 3D/K=4 case
# to keep the artifact set small.
ABLATION_CHUNKS = [16384, 262144]
ABLATION_VARIANT = (3, 4)


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(args, outs):
    """Manifest-side description of an executable signature."""
    def one(name, s):
        return {"name": name, "shape": list(s.shape), "dtype": s.dtype.name}
    return (
        [one(n, s) for n, s in args],
        [one(n, s) for n, s in outs],
    )


def lower_variant(d: int, k: int, chunk: int, tile_n: int):
    """Lower the four programs for one variant; yield manifest entries.

    Iteration-loop programs (`stats_partial`, `fused_stats`) return only
    the per-cluster statistics — a few hundred bytes per call — while
    `assign` is a separate program the engines run once after
    convergence (§Perf L2-1: transferring chunk-sized assignments every
    call dominated the tuple fetch).
    """
    f32, i32 = jnp.float32, jnp.int32
    x = _spec((chunk, d), f32)
    mu = _spec((k, d), f32)
    nv = _spec((1,), i32)
    sums = _spec((k, d), f32)
    counts = _spec((k,), f32)
    sse = _spec((1,), f32)
    assign = _spec((chunk,), i32)
    shift = _spec((1,), f32)

    sp = jax.jit(model.make_stats_partial(d, k, chunk, tile_n))
    ao = jax.jit(model.make_assign_only(d, k, chunk, tile_n))
    fs = jax.jit(model.make_fused_stats(d, k, chunk, tile_n))
    fin = jax.jit(model.make_finalize(d, k))

    yield (
        f"stats_partial_d{d}_k{k}_c{chunk}",
        sp.lower(x, mu, nv),
        _sig(
            [("x", x), ("mu", mu), ("n_valid", nv)],
            [("sums", sums), ("counts", counts), ("sse", sse)],
        ),
        {"kind": "stats_partial", "d": d, "k": k, "chunk": chunk, "tile_n": tile_n},
    )
    yield (
        f"assign_d{d}_k{k}_c{chunk}",
        ao.lower(x, mu, nv),
        _sig(
            [("x", x), ("mu", mu), ("n_valid", nv)],
            [("assign", assign)],
        ),
        {"kind": "assign", "d": d, "k": k, "chunk": chunk, "tile_n": tile_n},
    )
    yield (
        f"fused_stats_d{d}_k{k}_c{chunk}",
        fs.lower(x, mu, sums, counts, sse, nv),
        _sig(
            [("x", x), ("mu", mu), ("acc_sums", sums), ("acc_counts", counts),
             ("acc_sse", sse), ("n_valid", nv)],
            [("sums", sums), ("counts", counts), ("sse", sse)],
        ),
        {"kind": "fused_stats", "d": d, "k": k, "chunk": chunk, "tile_n": tile_n},
    )
    yield (
        f"finalize_d{d}_k{k}",
        fin.lower(sums, counts, mu),
        _sig(
            [("sums", sums), ("counts", counts), ("mu_old", mu)],
            [("mu_new", mu), ("shift", shift)],
        ),
        {"kind": "finalize", "d": d, "k": k, "chunk": 0, "tile_n": 0},
    )


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output dir")
    parser.add_argument("--tile", type=int, default=DEFAULT_TILE)
    parser.add_argument(
        "--no-ablation", action="store_true",
        help="skip the chunk-size ablation artifacts",
    )
    args = parser.parse_args()
    os.makedirs(args.out, exist_ok=True)

    jobs = [
        (d, k, chunk, min(args.tile, chunk))
        for d, k in VARIANTS
        for chunk in CHUNKS
    ]
    if not args.no_ablation:
        d, k = ABLATION_VARIANT
        for c in ABLATION_CHUNKS:
            jobs.append((d, k, c, min(args.tile, c)))

    entries = []
    seen = set()
    for d, k, chunk, tile_n in jobs:
        for name, lowered, (ins, outs), meta in lower_variant(d, k, chunk, tile_n):
            if name in seen:  # finalize_d{d}_k{k} repeats across chunk jobs
                continue
            seen.add(name)
            text = to_hlo_text(lowered)
            fname = f"{name}.hlo.txt"
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(text)
            entries.append({
                "name": name,
                "file": fname,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                **meta,
                "inputs": ins,
                "outputs": outs,
            })
            print(f"  lowered {name}: {len(text)} chars")

    manifest = {
        "format": 1,
        "default_chunk": DEFAULT_CHUNK,
        "default_tile": args.tile,
        "executables": entries,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
