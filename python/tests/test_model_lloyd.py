"""L2 integration: full Lloyd iterations chained through the AOT programs.

Drives the exact program sequence the rust engines will drive —
``assign_partial`` per chunk -> host merge -> ``finalize`` — and checks
it against a plain-jnp Lloyd implementation step-for-step, plus
convergence behaviour on a well-separated mixture.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is absent from the offline image (DESIGN.md §8); skip this
# module rather than erroring at collection time
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def _gmm(rng, n, d, k_true, spread=12.0):
    centers = rng.normal(size=(k_true, d)) * spread
    idx = rng.integers(0, k_true, size=n)
    x = centers[idx] + rng.normal(size=(n, d))
    return x.astype(np.float32), centers.astype(np.float32)


def _jnp_lloyd(x, mu0, iters):
    """Plain-jnp Lloyd, the semantic reference for the chained programs."""
    mu = jnp.asarray(mu0)
    xs = jnp.asarray(x)
    n = x.shape[0]
    hist = []
    for _ in range(iters):
        _, sums, counts, sse = ref.partial_stats(
            xs, mu, jnp.asarray(n, dtype=jnp.int32)
        )
        mu_new, shift = ref.finalize(sums, counts, mu)
        hist.append((float(sse), float(shift)))
        mu = mu_new
    return np.asarray(mu), hist


def _chained_lloyd(x, mu0, iters, chunk, tile_n):
    """Lloyd via the AOT-shaped programs, streaming padded chunks."""
    n, d = x.shape
    k = mu0.shape[0]
    ap = model.make_assign_partial(d, k, chunk, tile_n)
    fin = model.make_finalize(d, k)
    mu = jnp.asarray(mu0)
    hist = []
    for _ in range(iters):
        sums = np.zeros((k, d), np.float32)
        counts = np.zeros((k,), np.float32)
        sse = 0.0
        for lo in range(0, n, chunk):
            sl = x[lo:lo + chunk]
            nv = sl.shape[0]
            if nv < chunk:  # pad the final partial chunk
                sl = np.concatenate(
                    [sl, np.zeros((chunk - nv, d), np.float32)]
                )
            _, s, c, e = ap(
                jnp.asarray(sl), mu, jnp.asarray([nv], dtype=jnp.int32)
            )
            sums += np.asarray(s)
            counts += np.asarray(c)
            sse += float(np.asarray(e)[0])
        mu_new, shift = fin(
            jnp.asarray(sums), jnp.asarray(counts), mu
        )
        hist.append((sse, float(np.asarray(shift)[0])))
        mu = mu_new
    return np.asarray(mu), hist


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    d=st.sampled_from([2, 3]),
    k=st.sampled_from([4, 8]),
    chunk_tiles=st.integers(1, 3),
)
def test_chained_matches_reference(seed, d, k, chunk_tiles):
    rng = np.random.default_rng(seed)
    x, _ = _gmm(rng, 500, d, k)
    mu0 = x[rng.choice(500, size=k, replace=False)]
    chunk = 64 * chunk_tiles  # forces padded final chunk (500 % chunk != 0)
    mu_a, hist_a = _chained_lloyd(x, mu0, 5, chunk, 64)
    mu_b, hist_b = _jnp_lloyd(x, mu0, 5)
    np.testing.assert_allclose(mu_a, mu_b, rtol=1e-3, atol=1e-3)
    for (sa, ea), (sb, eb) in zip(hist_a, hist_b):
        assert sa == np.testing.assert_allclose(sa, sb, rtol=1e-3) or True
        np.testing.assert_allclose(sa, sb, rtol=1e-3)
        np.testing.assert_allclose(ea, eb, rtol=1e-2, atol=1e-4)


def test_convergence_well_separated():
    """On a crisp mixture the chained Lloyd must converge: shift -> ~0 and
    SSE monotonically non-increasing (a Lloyd invariant)."""
    rng = np.random.default_rng(42)
    x, centers = _gmm(rng, 1000, 3, 4, spread=50.0)
    # Seed one centroid near each true component: with a crisp mixture,
    # Lloyd must then recover the generating centers (random init can
    # legitimately land in a local minimum — not what this test checks).
    mu0 = (centers + rng.normal(size=centers.shape) * 2.0).astype(np.float32)
    mu, hist = _chained_lloyd(x, mu0, 12, 256, 64)
    sses = [s for s, _ in hist]
    assert all(b <= a * (1 + 1e-4) for a, b in zip(sses, sses[1:])), sses
    assert hist[-1][1] < 1e-3  # converged: centroid shift ~ 0
    # recovered centroids match the true ones up to permutation
    from itertools import permutations
    best = min(
        np.abs(mu[list(p)] - centers).max() for p in permutations(range(4))
    )
    assert best < 1.0


def test_fused_offload_sequence_matches_partial():
    """The offload engine's fused_step streaming == worker assign_partial
    merging, for a 3-chunk dataset (engines must agree)."""
    rng = np.random.default_rng(9)
    d, k, chunk = 3, 4, 128
    x, _ = _gmm(rng, 3 * chunk, d, k)
    mu = jnp.asarray(x[:k].copy())
    ap = model.make_assign_partial(d, k, chunk, 64)
    fs = model.make_fused_step(d, k, chunk, 64)
    nv = jnp.asarray([chunk], dtype=jnp.int32)

    # worker path: independent partials merged on host
    sums = np.zeros((k, d), np.float32)
    counts = np.zeros((k,), np.float32)
    sse = 0.0
    for lo in range(0, 3 * chunk, chunk):
        _, s, c, e = ap(jnp.asarray(x[lo:lo + chunk]), mu, nv)
        sums += np.asarray(s); counts += np.asarray(c); sse += float(np.asarray(e)[0])

    # offload path: accumulators streamed through fused_step
    s = jnp.zeros((k, d), jnp.float32)
    c = jnp.zeros((k,), jnp.float32)
    e = jnp.zeros((1,), jnp.float32)
    for lo in range(0, 3 * chunk, chunk):
        _, s, c, e = fs(jnp.asarray(x[lo:lo + chunk]), mu, s, c, e, nv)

    np.testing.assert_allclose(np.asarray(s), sums, rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c), counts, atol=1e-3)
    np.testing.assert_allclose(float(np.asarray(e)[0]), sse, rtol=1e-3)
