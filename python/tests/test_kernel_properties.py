"""Additional L1 kernel properties beyond point-wise oracle equality:
dtype policy, centroid-permutation equivariance, translation robustness,
and sentinel-padding safety under hypothesis sweeps.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is absent from the offline image (DESIGN.md §8); skip this
# module rather than erroring at collection time
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import lloyd as L


def _run(x, mu, n_valid, tile=64):
    n, d = x.shape
    k = mu.shape[0]
    ap = model.make_assign_partial(d, k, n, tile)
    return ap(
        jnp.asarray(x), jnp.asarray(mu), jnp.asarray([n_valid], dtype=jnp.int32)
    )


# ------------------------------------------------------------- dtypes

def test_f32_is_the_artifact_dtype():
    """The AOT contract is f32 (manifest + rust runtime); the kernel
    must produce f32 stats and i32 assignments from f32 inputs."""
    rng = np.random.default_rng(0)
    x = rng.normal(size=(128, 3)).astype(np.float32)
    mu = rng.normal(size=(4, 3)).astype(np.float32)
    a, sums, counts, sse = _run(x, mu, 128)
    assert a.dtype == jnp.int32
    assert sums.dtype == jnp.float32
    assert counts.dtype == jnp.float32
    assert sse.dtype == jnp.float32


def test_f64_inputs_follow_jax_x64_policy():
    """Without jax_enable_x64, f64 inputs silently demote to f32 —
    document the behavior the build relies on (the AOT path only ever
    traces f32 ShapeDtypeStructs, so this is belt-and-braces)."""
    rng = np.random.default_rng(1)
    x = rng.normal(size=(64, 2)).astype(np.float64)
    mu = rng.normal(size=(4, 2)).astype(np.float64)
    a, sums, _, _ = _run(x, mu, 64)
    assert sums.dtype == jnp.float32
    assert a.dtype == jnp.int32


# -------------------------------------------------- equivariance sweeps

@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), k=st.sampled_from([4, 8]))
def test_centroid_permutation_equivariance(seed, k):
    """Permuting centroid rows permutes assignments and per-cluster
    stats identically — no hidden order dependence in the one-hot
    matmul accumulation."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(128, 3)).astype(np.float32)
    mu = rng.normal(size=(k, 3)).astype(np.float32) * 3.0
    perm = rng.permutation(k)

    a1, s1, c1, e1 = _run(x, mu, 128)
    a2, s2, c2, e2 = _run(x, mu[perm], 128)

    # mapping: cluster j in permuted run == cluster perm[j] in original
    a2 = np.asarray(a2)
    remapped = np.asarray([perm[j] for j in a2])
    np.testing.assert_array_equal(remapped, np.asarray(a1))
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s1)[perm], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(c2), np.asarray(c1)[perm], atol=1e-5)
    np.testing.assert_allclose(float(e2[0]), float(e1[0]), rtol=1e-5)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    shift=st.floats(-50.0, 50.0),
)
def test_translation_equivariance(seed, shift):
    """Translating data and centroids together must not change the
    assignment (distances are translation invariant); SSE unchanged."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(128, 2)).astype(np.float32)
    mu = rng.normal(size=(4, 2)).astype(np.float32) * 2.0
    a1, _, c1, e1 = _run(x, mu, 128)
    a2, _, c2, e2 = _run(x + np.float32(shift), mu + np.float32(shift), 128)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), atol=1e-5)
    # the ||x||²−2x·μ+||μ||² expansion loses precision as |shift| grows;
    # tolerance scales accordingly
    tol = 1e-3 + abs(shift) * 2e-4
    np.testing.assert_allclose(float(e1[0]), float(e2[0]), rtol=tol, atol=tol)


# ----------------------------------------------------- sentinel safety

@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1), k=st.sampled_from([1, 3, 5, 11]))
def test_sentinel_rows_never_win_even_for_huge_data(seed, k):
    """K-padding rows must never be selected even at extreme data
    magnitudes (|x| up to 1e6)."""
    rng = np.random.default_rng(seed)
    kp = L.pad_k(k)
    x = (rng.normal(size=(64, 3)) * 1e6).astype(np.float32)
    mu = (rng.normal(size=(k, 3)) * 1e6).astype(np.float32)
    mu_p = L.pad_centroids(jnp.asarray(mu), kp)
    a, sums, counts, _ = L.lloyd_chunk(
        jnp.asarray(x), mu_p, jnp.asarray([64], dtype=jnp.int32), tile_n=64
    )
    a = np.asarray(a)
    assert a.max() < k, f"padding row selected: {a.max()} >= {k}"
    counts = np.asarray(counts)
    assert np.all(counts[k:] == 0.0), "padding rows accumulated counts"
    sums = np.asarray(sums)
    assert np.all(sums[k:] == 0.0), "padding rows accumulated sums"


def test_chunk_must_be_tile_multiple():
    with pytest.raises(ValueError, match="multiple"):
        L.lloyd_chunk(
            jnp.zeros((100, 2), jnp.float32),
            jnp.zeros((8, 2), jnp.float32),
            jnp.asarray([100], dtype=jnp.int32),
            tile_n=64,
        )
