"""AOT pipeline tests: lowering produces loadable HLO text and a
manifest whose signatures match what the rust runtime will assume.

These don't re-run the heavy full variant set; they lower one small
variant end-to-end and check the contract pieces (HLO text shape,
signature derivation, manifest completeness rules).
"""

from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_small():
    """One tiny variant (d=2, k=4, chunk=128, tile=64), all programs."""
    return list(aot.lower_variant(2, 4, 128, 64))


def test_four_programs_per_variant(lowered_small):
    kinds = [meta["kind"] for _, _, _, meta in lowered_small]
    assert kinds == ["stats_partial", "assign", "fused_stats", "finalize"]


def test_hlo_text_parses_as_hlo(lowered_small):
    for name, lowered, _, _ in lowered_small:
        text = aot.to_hlo_text(lowered)
        # HLO text essentials: module header + ENTRY + ROOT tuple
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert "ROOT" in text, name
        # ids must be small (the whole point of the text round-trip:
        # xla_extension 0.5.1 rejects 64-bit instruction ids)
        assert "parameter(0)" in text, name


def test_signatures_match_program_outputs(lowered_small):
    """Manifest signature == actual jax eval shapes for every program."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(128, 2)).astype(np.float32))
    mu = jnp.asarray(rng.normal(size=(4, 2)).astype(np.float32))
    nv = jnp.asarray([100], dtype=jnp.int32)
    sums = jnp.zeros((4, 2), jnp.float32)
    counts = jnp.zeros((4,), jnp.float32)
    sse = jnp.zeros((1,), jnp.float32)

    args_by_kind = {
        "stats_partial": (x, mu, nv),
        "assign": (x, mu, nv),
        "fused_stats": (x, mu, sums, counts, sse, nv),
        "finalize": (sums, counts, mu),
    }
    makers = {
        "stats_partial": model.make_stats_partial(2, 4, 128, 64),
        "assign": model.make_assign_only(2, 4, 128, 64),
        "fused_stats": model.make_fused_stats(2, 4, 128, 64),
        "finalize": model.make_finalize(2, 4),
    }
    for name, _, (ins, outs), meta in lowered_small:
        kind = meta["kind"]
        result = makers[kind](*args_by_kind[kind])
        if not isinstance(result, tuple):
            result = (result,)
        assert len(result) == len(outs), name
        for got, spec in zip(result, outs):
            assert list(got.shape) == spec["shape"], (name, spec["name"])
            assert got.dtype.name == spec["dtype"], (name, spec["name"])
        assert len(args_by_kind[kind]) == len(ins), name


def test_stats_partial_drops_assign_everywhere(lowered_small):
    """stats_partial's HLO must not output a chunk-length i32 tensor
    (the assignment was the §Perf L2-1 transfer hog)."""
    for name, lowered, _, meta in lowered_small:
        if meta["kind"] != "stats_partial":
            continue
        text = aot.to_hlo_text(lowered)
        # the entry computation's ROOT tuple elements
        root = [l for l in text.splitlines() if "ROOT" in l and "tuple(" in l]
        assert root, name
        assert "s32[128]" not in root[-1], f"{name}: assign leaked into outputs"


def test_manifest_main_writes_complete_set(tmp_path, monkeypatch):
    """Run aot.main with a tiny matrix and verify the manifest indexes
    every file it wrote."""
    monkeypatch.setattr(aot, "VARIANTS", [(2, 4)])
    monkeypatch.setattr(aot, "CHUNKS", [128])
    monkeypatch.setattr(aot, "ABLATION_CHUNKS", [])
    monkeypatch.setattr(
        "sys.argv", ["aot", "--out", str(tmp_path), "--tile", "64"]
    )
    aot.main()
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["format"] == 1
    names = {e["name"] for e in manifest["executables"]}
    assert names == {
        "stats_partial_d2_k4_c128",
        "assign_d2_k4_c128",
        "fused_stats_d2_k4_c128",
        "finalize_d2_k4",
    }
    for e in manifest["executables"]:
        f = tmp_path / e["file"]
        assert f.exists(), e["file"]
        import hashlib
        assert hashlib.sha256(f.read_bytes()).hexdigest() == e["sha256"]


def test_tile_must_divide_chunk():
    with pytest.raises(ValueError):
        ap = model.make_assign_partial(2, 4, 100, 64)  # 100 % 64 != 0
        x = jnp.zeros((100, 2), jnp.float32)
        mu = jnp.zeros((4, 2), jnp.float32)
        ap(x, mu, jnp.asarray([100], dtype=jnp.int32))


def test_lowering_is_deterministic():
    """Same variant lowers to byte-identical HLO text (artifact caching
    and sha256 integrity depend on this)."""
    a = list(aot.lower_variant(2, 4, 128, 64))
    b = list(aot.lower_variant(2, 4, 128, 64))
    for (n1, l1, _, _), (n2, l2, _, _) in zip(a, b):
        assert n1 == n2
        assert aot.to_hlo_text(l1) == aot.to_hlo_text(l2)
