"""L1 kernel vs pure-jnp oracle: hypothesis sweeps over shapes and data.

The kernel is only trusted through this equivalence (DESIGN.md §2). We
sweep chunk/tile/d/k/n_valid and several data regimes (generic normal,
clustered, duplicated points, extreme coordinates) and compare every
output against ``ref.py`` with f32-appropriate tolerances.

Assignment ties: the kernel computes distances via the matmul expansion,
the oracle via explicit differences; at exact ties (or near-ties within
f32 noise) argmin may legitimately differ. Comparisons therefore accept
assignment mismatches only where the two candidate distances are within
a relative epsilon, and always check the *aggregate* statistics with
tolerances scaled to the data magnitude.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is absent from the offline image (DESIGN.md §8); skip this
# module rather than erroring at collection time
pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import lloyd as L
from compile.kernels import ref
from compile import model


def _mk(rng, n, d, k, scale=1.0, clustered=False):
    if clustered:
        centers = rng.normal(size=(k, d)) * 5.0 * scale
        idx = rng.integers(0, k, size=n)
        x = centers[idx] + rng.normal(size=(n, d)) * 0.3 * scale
    else:
        x = rng.normal(size=(n, d)) * scale
    mu = rng.normal(size=(k, d)) * scale
    return x.astype(np.float32), mu.astype(np.float32)


def _check_assign(x, mu, got, want):
    """Assignments must agree except at near-ties (see module docstring)."""
    got = np.asarray(got)
    want = np.asarray(want)
    if np.array_equal(got, want):
        return
    d2 = np.asarray(ref.sq_distances(jnp.asarray(x), jnp.asarray(mu)))
    bad = np.nonzero(got != want)[0]
    for i in bad:
        assert got[i] >= 0 and want[i] >= 0, f"validity mask differs at row {i}"
        a, b = d2[i, got[i]], d2[i, want[i]]
        denom = max(abs(a), abs(b), 1e-6)
        assert abs(a - b) / denom < 1e-3, (
            f"row {i}: kernel chose {got[i]} (d2={a}), ref {want[i]} (d2={b})"
        )


def _run_and_compare(x, mu, n_valid, tile_n):
    n, d = x.shape
    k = mu.shape[0]
    ap = model.make_assign_partial(d, k, n, tile_n)
    nv = jnp.asarray([n_valid], dtype=jnp.int32)
    a, sums, counts, sse = ap(jnp.asarray(x), jnp.asarray(mu), nv)
    ra, rsums, rcounts, rsse = ref.partial_stats(jnp.asarray(x), jnp.asarray(mu), nv)

    _check_assign(x, mu, a, ra)
    scale = float(np.abs(x).max()) + 1.0
    np.testing.assert_allclose(
        np.asarray(counts), np.asarray(rcounts), atol=n_valid * 1e-3 + 0.5
    )
    # counts must be exact integers
    assert float(np.asarray(counts).sum()) == pytest.approx(n_valid, abs=1e-3)
    np.testing.assert_allclose(
        np.asarray(sums), np.asarray(rsums),
        rtol=1e-4, atol=scale * max(n_valid, 1) * 1e-5 + 1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(sse)[0], float(rsse),
        rtol=1e-3, atol=scale * scale * max(n_valid, 1) * 1e-5 + 1e-4,
    )
    return a, sums, counts, sse


# ---------------------------------------------------------------- sweeps

@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    d=st.sampled_from([2, 3]),
    k=st.sampled_from([4, 8, 11]),
    tiles=st.integers(1, 4),
    tile_n=st.sampled_from([32, 64, 256]),
    frac_valid=st.floats(0.01, 1.0),
    clustered=st.booleans(),
)
def test_partial_stats_sweep(seed, d, k, tiles, tile_n, frac_valid, clustered):
    rng = np.random.default_rng(seed)
    n = tiles * tile_n
    n_valid = max(1, int(n * frac_valid))
    x, mu = _mk(rng, n, d, k, clustered=clustered)
    _run_and_compare(x, mu, n_valid, tile_n)


@settings(max_examples=10, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_partial_stats_scales(seed, scale):
    """Extreme coordinate magnitudes must not break the expansion."""
    rng = np.random.default_rng(seed)
    x, mu = _mk(rng, 128, 3, 4, scale=scale)
    _run_and_compare(x, mu, 128, 64)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**32 - 1))
def test_duplicate_points(seed):
    """Many exactly-duplicated points (ties everywhere in the data)."""
    rng = np.random.default_rng(seed)
    base, mu = _mk(rng, 16, 2, 4)
    x = np.repeat(base, 8, axis=0)  # 128 rows, 8 copies each
    _run_and_compare(x, mu, 128, 32)


def test_all_points_one_cluster():
    """Degenerate: one centroid is vastly closer; all counts land on it."""
    rng = np.random.default_rng(7)
    x = rng.normal(size=(128, 3)).astype(np.float32)
    mu = np.full((4, 3), 100.0, dtype=np.float32)
    mu[2] = 0.0
    _, _, counts, _ = _run_and_compare(x, mu, 128, 64)
    counts = np.asarray(counts)
    assert counts[2] == 128 and counts.sum() == 128


def test_n_valid_zero_statistics_empty():
    """All-padding chunk contributes nothing."""
    rng = np.random.default_rng(1)
    x, mu = _mk(rng, 64, 2, 4)
    ap = model.make_assign_partial(2, 4, 64, 32)
    a, sums, counts, sse = ap(
        jnp.asarray(x), jnp.asarray(mu), jnp.asarray([0], dtype=jnp.int32)
    )
    assert np.all(np.asarray(a) == -1)
    assert np.all(np.asarray(sums) == 0)
    assert np.all(np.asarray(counts) == 0)
    assert float(np.asarray(sse)[0]) == 0.0


def test_single_tile_equals_multi_tile():
    """Grid decomposition must not change the chunk-level statistics."""
    rng = np.random.default_rng(3)
    x, mu = _mk(rng, 256, 3, 8, clustered=True)
    nv = jnp.asarray([256], dtype=jnp.int32)
    ap1 = model.make_assign_partial(3, 8, 256, 256)
    ap4 = model.make_assign_partial(3, 8, 256, 64)
    a1, s1, c1, e1 = ap1(jnp.asarray(x), jnp.asarray(mu), nv)
    a4, s4, c4, e4 = ap4(jnp.asarray(x), jnp.asarray(mu), nv)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a4))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s4), rtol=1e-5, atol=1e-4)
    np.testing.assert_array_equal(np.asarray(c1), np.asarray(c4))
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e4), rtol=1e-4)


# ------------------------------------------------------------ fused_step

@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    d=st.sampled_from([2, 3]),
    k=st.sampled_from([4, 8]),
)
def test_fused_step_accumulates(seed, d, k):
    rng = np.random.default_rng(seed)
    x, mu = _mk(rng, 128, d, k)
    acc_s = rng.normal(size=(k, d)).astype(np.float32)
    acc_c = rng.integers(0, 50, size=(k,)).astype(np.float32)
    acc_e = np.array([3.5], dtype=np.float32)
    nv = jnp.asarray([100], dtype=jnp.int32)

    fs = model.make_fused_step(d, k, 128, 64)
    a, s, c, e = fs(
        jnp.asarray(x), jnp.asarray(mu),
        jnp.asarray(acc_s), jnp.asarray(acc_c), jnp.asarray(acc_e), nv,
    )
    ra, rs, rc, re = ref.fused_step(
        jnp.asarray(x), jnp.asarray(mu),
        jnp.asarray(acc_s), jnp.asarray(acc_c), jnp.asarray(acc_e[0]), nv,
    )
    _check_assign(x, mu, a, ra)
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c), np.asarray(rc), atol=1e-3)
    np.testing.assert_allclose(np.asarray(e)[0], float(re), rtol=1e-3)


def test_fused_step_chain_equals_batch():
    """Streaming two chunks through fused_step == one partial over both."""
    rng = np.random.default_rng(11)
    d, k = 3, 4
    x1, mu = _mk(rng, 128, d, k)
    x2, _ = _mk(rng, 128, d, k)
    nv = jnp.asarray([128], dtype=jnp.int32)
    fs = model.make_fused_step(d, k, 128, 64)
    z_s = jnp.zeros((k, d), jnp.float32)
    z_c = jnp.zeros((k,), jnp.float32)
    z_e = jnp.zeros((1,), jnp.float32)
    _, s, c, e = fs(jnp.asarray(x1), jnp.asarray(mu), z_s, z_c, z_e, nv)
    _, s, c, e = fs(jnp.asarray(x2), jnp.asarray(mu), s, c, e, nv)

    both = np.concatenate([x1, x2])
    _, rs, rc, re = ref.partial_stats(
        jnp.asarray(both), jnp.asarray(mu), jnp.asarray([256], dtype=jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(s), np.asarray(rs), rtol=1e-4, atol=1e-3)
    np.testing.assert_allclose(np.asarray(c), np.asarray(rc), atol=1e-3)
    np.testing.assert_allclose(np.asarray(e)[0], float(re), rtol=1e-3)


# -------------------------------------------------------------- finalize

@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**32 - 1),
    d=st.sampled_from([2, 3]),
    k=st.sampled_from([4, 8, 11]),
    empty=st.integers(0, 3),
)
def test_finalize(seed, d, k, empty):
    rng = np.random.default_rng(seed)
    sums = rng.normal(size=(k, d)).astype(np.float32) * 100
    counts = rng.integers(1, 1000, size=(k,)).astype(np.float32)
    counts[:empty] = 0.0  # empty clusters keep old centroid
    mu_old = rng.normal(size=(k, d)).astype(np.float32)

    fin = model.make_finalize(d, k)
    mu_new, shift = fin(jnp.asarray(sums), jnp.asarray(counts), jnp.asarray(mu_old))
    rmu, rshift = ref.finalize(jnp.asarray(sums), jnp.asarray(counts), jnp.asarray(mu_old))
    np.testing.assert_allclose(np.asarray(mu_new), np.asarray(rmu), rtol=1e-6)
    np.testing.assert_allclose(float(np.asarray(shift)[0]), float(rshift), rtol=1e-5)
    # empty clusters: unchanged centroids
    np.testing.assert_array_equal(np.asarray(mu_new)[:empty], mu_old[:empty])


def test_finalize_converged_zero_shift():
    """If sums/counts reproduce mu_old exactly, shift must be 0."""
    k, d = 4, 3
    mu_old = np.arange(k * d, dtype=np.float32).reshape(k, d)
    counts = np.full((k,), 5.0, dtype=np.float32)
    sums = mu_old * counts[:, None]
    fin = model.make_finalize(d, k)
    mu_new, shift = fin(jnp.asarray(sums), jnp.asarray(counts), jnp.asarray(mu_old))
    np.testing.assert_allclose(np.asarray(mu_new), mu_old, rtol=1e-6)
    assert float(np.asarray(shift)[0]) < 1e-10


# ------------------------------------------------------------- pad utils

@pytest.mark.parametrize("k,kp", [(1, 8), (4, 8), (8, 8), (9, 16), (11, 16), (16, 16), (17, 24)])
def test_pad_k(k, kp):
    assert L.pad_k(k) == kp


def test_pad_centroids_sentinel_never_wins():
    rng = np.random.default_rng(5)
    mu = rng.normal(size=(11, 3)).astype(np.float32)
    mu_p = L.pad_centroids(jnp.asarray(mu), 16)
    assert mu_p.shape == (16, 3)
    x = rng.normal(size=(64, 3)).astype(np.float32) * 1e3
    d2 = ref.sq_distances(jnp.asarray(x), mu_p)
    a = np.asarray(jnp.argmin(d2, axis=1))
    assert a.max() < 11
