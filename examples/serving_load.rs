//! Serving load test: train a model, start the assignment service
//! in-process, drive it with concurrent clients, and report latency /
//! throughput percentiles — the serving-paper-style evaluation of the
//! L3 router/batcher.
//!
//!     cargo run --release --offline --example serving_load

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::Instant;

use parakmeans::data::gmm::MixtureSpec;
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::serve::{serve, BatcherConfig, Response, ServeConfig};

const CLIENTS: usize = 8;
const REQUESTS_PER_CLIENT: usize = 50;
const POINTS_PER_REQUEST: usize = 32;

type DynError = Box<dyn std::error::Error + Send + Sync>;

fn main() -> Result<(), DynError> {
    // 1. train
    let ds = MixtureSpec::paper_3d(4).generate(50_000, 42);
    let model = kmeans::serial::run(&ds, &KmeansConfig::new(4).with_seed(7));
    println!(
        "trained K=4 on {} points ({} iters, sse {:.3e})",
        ds.len(),
        model.iterations,
        model.sse
    );

    // 2. serve on an ephemeral port
    let cfg = ServeConfig {
        addr: "127.0.0.1:0".into(),
        batcher: BatcherConfig::default(),
        ..Default::default()
    };
    let server = serve(cfg, model.centroids.clone(), 3, 4)?;
    let addr = server.local_addr;
    println!(
        "serving on {addr}; driving {CLIENTS} clients × {REQUESTS_PER_CLIENT} requests × {POINTS_PER_REQUEST} points"
    );

    // 3. drive concurrent clients, collecting per-request latency
    let t0 = Instant::now();
    let handles: Vec<_> = (0..CLIENTS)
        .map(|c| {
            std::thread::spawn(move || -> Result<Vec<f64>, DynError> {
                let mut rng = parakmeans::rng::Pcg64::new(c as u64, 0x10AD);
                let mut conn = TcpStream::connect(addr)?;
                conn.set_nodelay(true)?;
                let mut reader = BufReader::new(conn.try_clone()?);
                let mut latencies = Vec::with_capacity(REQUESTS_PER_CLIENT);
                for r in 0..REQUESTS_PER_CLIENT {
                    let pts: Vec<String> = (0..POINTS_PER_REQUEST)
                        .map(|_| {
                            format!(
                                "[{}, {}, {}]",
                                rng.next_f32() * 30.0,
                                rng.next_f32() * 30.0,
                                rng.next_f32() * 30.0
                            )
                        })
                        .collect();
                    let line = format!(
                        r#"{{"id": {}, "points": [{}]}}"#,
                        c * REQUESTS_PER_CLIENT + r,
                        pts.join(", ")
                    );
                    let t = Instant::now();
                    writeln!(conn, "{line}")?;
                    let mut resp = String::new();
                    reader.read_line(&mut resp)?;
                    latencies.push(t.elapsed().as_secs_f64());
                    match Response::parse(resp.trim())? {
                        Response::Ok { clusters, .. } => {
                            if clusters.len() != POINTS_PER_REQUEST {
                                return Err(format!(
                                    "short reply: {} clusters",
                                    clusters.len()
                                )
                                .into());
                            }
                        }
                        Response::Err { error, .. } => {
                            return Err(format!("server error: {error}").into())
                        }
                    }
                }
                Ok(latencies)
            })
        })
        .collect();

    let mut latencies: Vec<f64> = Vec::new();
    for h in handles {
        latencies.extend(h.join().expect("client panicked")?);
    }
    let wall = t0.elapsed().as_secs_f64();
    server.shutdown();

    // 4. report
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |q: f64| latencies[(q * (latencies.len() - 1) as f64) as usize] * 1e3;
    let total_requests = CLIENTS * REQUESTS_PER_CLIENT;
    let total_points = total_requests * POINTS_PER_REQUEST;
    println!("requests    : {total_requests} ({total_points} points) in {wall:.3}s");
    println!(
        "throughput  : {:.0} req/s, {:.0} points/s",
        total_requests as f64 / wall,
        total_points as f64 / wall
    );
    println!("latency p50 : {:.2} ms", pct(0.50));
    println!("latency p90 : {:.2} ms", pct(0.90));
    println!("latency p99 : {:.2} ms", pct(0.99));
    assert!(pct(0.50) < 250.0, "median latency degenerate");
    println!("serving_load OK");
    Ok(())
}
