//! Distance-based anomaly detection — the paper's second motivating
//! application.
//!
//! Clusters normal traffic (a 3D mixture) with the shared engine, then
//! flags points whose distance to their nearest centroid exceeds a
//! per-cluster threshold (mean + 3σ of member distances). Injected
//! anomalies far from every component must be recalled.
//!
//!     cargo run --release --offline --example anomaly_detection

use parakmeans::config::RunConfig;
use parakmeans::coordinator::shared;
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::data::Dataset;
use parakmeans::linalg;
use parakmeans::rng::Pcg64;

fn main() -> parakmeans::Result<()> {
    // 1. Normal data: 4-component 3D mixture, 40k points.
    let spec = MixtureSpec::paper_3d(4);
    let normal = spec.generate(40_000, 11);

    // 2. Inject 200 anomalies sampled uniformly in a huge box.
    let mut rng = Pcg64::new(99, 7);
    let mut all = Dataset::with_capacity(3, normal.len() + 200);
    for i in 0..normal.len() {
        all.push(normal.point(i));
    }
    let bounds = normal.bounds();
    let span: f32 = bounds.iter().map(|(lo, hi)| hi - lo).fold(0.0, f32::max);
    let mut injected = Vec::new();
    for _ in 0..200 {
        // well outside the data's bounding box
        let p = [
            bounds[0].1 + span * (0.5 + rng.next_f32()),
            bounds[1].1 + span * (0.5 + rng.next_f32()),
            bounds[2].1 + span * (0.5 + rng.next_f32()),
        ];
        injected.push(all.len());
        all.push(&p);
    }
    println!("dataset: {} normal + {} injected anomalies", normal.len(), injected.len());

    // 3. Cluster with the shared engine (p = 4 workers).
    let cfg = RunConfig { k: 4, seed: 5, ..Default::default() };
    let run = shared::run(&all, &cfg, 4)?;
    println!(
        "shared engine: {} iters, {:.3}s wall ({:.3}s testbed)",
        run.result.iterations, run.wall_secs, run.table_secs()
    );

    // 4. Per-cluster distance statistics -> thresholds (mean + 3σ).
    let k = run.result.k;
    let d = all.dim();
    let mut dist = vec![0.0f64; all.len()];
    let mut sum = vec![0.0f64; k];
    let mut sumsq = vec![0.0f64; k];
    let mut cnt = vec![0u64; k];
    for i in 0..all.len() {
        let a = run.result.assign[i] as usize;
        let c = &run.result.centroids[a * d..(a + 1) * d];
        let dd = linalg::sqdist_f64(all.point(i), c).sqrt();
        dist[i] = dd;
        sum[a] += dd;
        sumsq[a] += dd * dd;
        cnt[a] += 1;
    }
    let thresh: Vec<f64> = (0..k)
        .map(|c| {
            let mean = sum[c] / cnt[c] as f64;
            let var = (sumsq[c] / cnt[c] as f64 - mean * mean).max(0.0);
            mean + 3.0 * var.sqrt()
        })
        .collect();
    println!("per-cluster thresholds: {thresh:?}");

    // 5. Flag and score.
    let flagged: Vec<usize> = (0..all.len())
        .filter(|&i| dist[i] > thresh[run.result.assign[i] as usize])
        .collect();
    let injected_set: std::collections::HashSet<usize> = injected.iter().copied().collect();
    let true_pos = flagged.iter().filter(|i| injected_set.contains(i)).count();
    let recall = true_pos as f64 / injected.len() as f64;
    let precision = if flagged.is_empty() {
        0.0
    } else {
        true_pos as f64 / flagged.len() as f64
    };
    println!(
        "flagged {} points: recall {:.3}, precision {:.3}",
        flagged.len(),
        recall,
        precision
    );
    assert!(recall > 0.95, "missed injected anomalies: recall {recall}");
    assert!(precision > 0.3, "too many false alarms: precision {precision}");
    println!("anomaly_detection OK");
    Ok(())
}
