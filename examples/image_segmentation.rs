//! Image segmentation / color quantization — the paper's motivating
//! application ("image segmentation, anomaly detection, etc.").
//!
//! Renders a synthetic RGB test image (smooth gradients + shapes),
//! clusters its pixels in 3D color space with the offload engine
//! (K = 8 palette), and writes before/after PPM images plus the palette.
//!
//!     cargo run --release --offline --example image_segmentation

use std::io::Write;
use std::path::Path;

use parakmeans::config::RunConfig;
use parakmeans::coordinator::offload;
use parakmeans::data::Dataset;

const W: usize = 320;
const H: usize = 240;

/// Synthetic scene: vertical sky gradient, a sun disk, hills, water.
fn render_scene() -> Vec<[f32; 3]> {
    let mut px = Vec::with_capacity(W * H);
    for y in 0..H {
        for x in 0..W {
            let (fx, fy) = (x as f32 / W as f32, y as f32 / H as f32);
            // sky gradient
            let mut c = [0.35 + 0.3 * (1.0 - fy), 0.55 + 0.25 * (1.0 - fy), 0.9];
            // sun
            let (dx, dy) = (fx - 0.75, fy - 0.2);
            if (dx * dx + dy * dy).sqrt() < 0.09 {
                c = [1.0, 0.9, 0.3];
            }
            // hills (sine silhouette)
            let hill = 0.55 + 0.08 * (fx * 9.0).sin() + 0.05 * (fx * 23.0).cos();
            if fy > hill {
                c = [0.2 + 0.15 * fy, 0.45 + 0.2 * (1.0 - fy), 0.2];
            }
            // water
            if fy > 0.8 {
                let ripple = 0.03 * ((fx * 40.0 + fy * 60.0).sin());
                c = [0.15 + ripple, 0.3 + ripple, 0.55 + ripple];
            }
            px.push(c);
        }
    }
    px
}

fn write_ppm(path: &Path, pixels: &[[f32; 3]]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "P6\n{W} {H}\n255")?;
    for p in pixels {
        let bytes: Vec<u8> = p
            .iter()
            .map(|&v| (v.clamp(0.0, 1.0) * 255.0) as u8)
            .collect();
        f.write_all(&bytes)?;
    }
    Ok(())
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let pixels = render_scene();
    let out_dir = Path::new("results/examples");
    std::fs::create_dir_all(out_dir)?;
    write_ppm(&out_dir.join("scene_original.ppm"), &pixels)?;

    // pixels -> 3D dataset in color space
    let flat: Vec<f32> = pixels.iter().flat_map(|p| p.iter().copied()).collect();
    let ds = Dataset::from_vec(flat, 3)?;
    println!("segmenting {} pixels into 8 colors...", ds.len());

    let k = 8;
    let cfg = RunConfig { k, seed: 3, ..Default::default() }; // chunk auto
    let run = offload::run(&ds, &cfg)?;
    println!(
        "offload engine: {} iters (converged: {}), sse {:.4}, {:.3}s wall",
        run.result.iterations, run.result.converged, run.result.sse, run.wall_secs
    );

    // quantized image: replace each pixel by its centroid color
    let quant: Vec<[f32; 3]> = run
        .result
        .assign
        .iter()
        .map(|&a| {
            let c = run.result.centroid(a as usize);
            [c[0], c[1], c[2]]
        })
        .collect();
    write_ppm(&out_dir.join("scene_quantized_k8.ppm"), &quant)?;

    println!("palette:");
    for c in 0..k {
        let col = run.result.centroid(c);
        println!(
            "  cluster {c}: rgb({:>3},{:>3},{:>3})  {} px",
            (col[0] * 255.0) as u8,
            (col[1] * 255.0) as u8,
            (col[2] * 255.0) as u8,
            run.result.cluster_sizes()[c]
        );
    }
    // quantization must reduce per-pixel error vs a 1-color baseline
    let one = parakmeans::kmeans::serial::run(
        &ds,
        &parakmeans::kmeans::KmeansConfig::new(1).with_seed(3),
    );
    assert!(run.result.sse < one.sse * 0.25, "k=8 should beat k=1 by 4x+");
    println!(
        "wrote {} and {}",
        out_dir.join("scene_original.ppm").display(),
        out_dir.join("scene_quantized_k8.ppm").display()
    );
    Ok(())
}
