//! Quickstart: generate a dataset, cluster it three ways (serial rust,
//! AOT shared-memory engine, AOT offload engine), verify they agree.
//!
//!     make artifacts && cargo run --release --offline --example quickstart

use parakmeans::config::{Engine, RunConfig};
use parakmeans::coordinator::{offload, shared};
use parakmeans::data::gmm::MixtureSpec;
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::metrics;

fn main() -> parakmeans::Result<()> {
    // 1. A 3D mixture of 4 Gaussians, 50k points (the paper's small case).
    let ds = MixtureSpec::paper_3d(4).generate(50_000, 42);
    println!("dataset: {} points, {}D", ds.len(), ds.dim());

    // 2. Pure-rust serial Lloyd (the paper's baseline).
    let kc = KmeansConfig::new(4).with_seed(7);
    let t0 = std::time::Instant::now();
    let serial = kmeans::serial::run(&ds, &kc);
    println!(
        "serial : {} iters, sse {:.4e}, {:.3}s",
        serial.iterations,
        serial.sse,
        t0.elapsed().as_secs_f64()
    );

    // 3. The AOT engines (python never runs here — artifacts were
    //    compiled once by `make artifacts`).
    let cfg = RunConfig { engine: Engine::Shared, k: 4, seed: 7, ..Default::default() };
    let sh = shared::run(&ds, &cfg, 4)?;
    println!(
        "shared : {} iters, sse {:.4e}, {:.3}s wall (+{:.2}s setup), {:.3}s testbed p=4",
        sh.result.iterations,
        sh.result.sse,
        sh.wall_secs,
        sh.setup_secs,
        sh.table_secs()
    );

    let off = offload::run(&ds, &cfg)?;
    println!(
        "offload: {} iters, sse {:.4e}, {:.3}s wall (+{:.2}s setup)",
        off.result.iterations,
        off.result.sse,
        off.wall_secs,
        off.setup_secs
    );

    // 4. All three must produce the same clustering (paper Figures 1-6).
    let ari_sh = metrics::adjusted_rand_index(&serial.assign, &sh.result.assign);
    let ari_off = metrics::adjusted_rand_index(&serial.assign, &off.result.assign);
    println!("ARI serial/shared  = {ari_sh:.5}");
    println!("ARI serial/offload = {ari_off:.5}");
    assert!(ari_sh > 0.999 && ari_off > 0.999, "engines disagree");

    // 5. And recover the generating mixture.
    let ari_truth = metrics::adjusted_rand_index(&serial.assign, ds.truth.as_ref().unwrap());
    println!("ARI vs ground truth = {ari_truth:.5}");
    println!("quickstart OK");
    Ok(())
}
