//! End-to-end driver (DESIGN.md §6): the full system on the paper's 3D
//! workload family — data generation → serial baseline → shared-memory
//! engine sweep (p = 2..16) → offload engine → metrics → figures.
//!
//! Proves all layers compose: L3 coordination (this binary), AOT
//! executables from the L2 jax programs, and the L1 Pallas kernel
//! inside them. Verifies every engine produces the serial clustering
//! (ARI ≥ 0.99) and prints Table-1/3/5-style rows plus speedup and
//! efficiency. Recorded in EXPERIMENTS.md §E2E.
//!
//! Scale: PARAKM_SCALE=full reproduces the paper sizes (slow on 1
//! core); default smoke is the same structure at 1/50 size.
//!
//!     cargo run --release --offline --example scaling_benchmark

use parakmeans::config::{Engine, RunConfig};
use parakmeans::coordinator::{offload, shared};
use parakmeans::data::gmm::workloads;
use parakmeans::eval::{self, Scale};
use parakmeans::kmeans::{self, KmeansConfig};
use parakmeans::metrics;
use parakmeans::util::tables;

fn main() -> parakmeans::Result<()> {
    let scale = Scale::from_env();
    let k = workloads::K_3D;
    println!("scaling_benchmark: 3D family, K={k}, scale {scale:?}\n");

    let mut rows: Vec<Vec<String>> = Vec::new();
    for &n_full in &workloads::SIZES_3D {
        let n = scale.apply(n_full);
        let ds = eval::paper_dataset(3, n);

        // serial baseline (Table 1 analog)
        let kc = KmeansConfig::new(k).with_seed(42);
        let t0 = std::time::Instant::now();
        let serial = kmeans::serial::run(&ds, &kc);
        let t_serial = t0.elapsed().as_secs_f64();

        // shared engine sweep (Table 3 analog)
        let cfg = RunConfig { engine: Engine::Shared, k, seed: 42, ..Default::default() };
        let mut shared_times = Vec::new();
        for p in workloads::THREADS {
            let run = shared::run(&ds, &cfg, p)?;
            let ari = metrics::adjusted_rand_index(&serial.assign, &run.result.assign);
            assert!(ari > 0.99, "shared p={p} diverged: ARI {ari}");
            assert!(
                run.result.iterations == serial.iterations,
                "iteration mismatch at p={p}"
            );
            shared_times.push(run.table_secs());
        }

        // offload engine (Table 5 analog)
        let off = offload::run(&ds, &cfg)?;
        let ari = metrics::adjusted_rand_index(&serial.assign, &off.result.assign);
        assert!(ari > 0.99, "offload diverged: ARI {ari}");

        let psi8 = metrics::speedup(shared_times[0], shared_times[2]); // p=2 -> p=8
        println!(
            "N={n:<8} iters={:<3} serial={:<9.4}s shared(p=2..16)={:?} offload={:.4}s (raw {:.4}s)  psi(2->8)={:.2}",
            serial.iterations,
            t_serial,
            shared_times.iter().map(|t| (t * 1e4).round() / 1e4).collect::<Vec<_>>(),
            off.table_secs(),
            off.wall_secs,
            psi8,
        );
        let mut row = vec![n.to_string(), tables::secs(t_serial)];
        row.extend(shared_times.iter().map(|&t| tables::secs(t)));
        row.push(tables::secs(off.table_secs()));
        rows.push(row);
    }

    println!();
    println!(
        "{}",
        tables::render(
            "E2E: 3D family — serial vs shared(p) vs offload (seconds)",
            &["N", "serial", "p=2", "p=4", "p=8", "p=16", "offload"],
            &rows
        )
    );
    println!("scaling_benchmark OK — all engines agree with serial (ARI > 0.99)");
    Ok(())
}
